//! Perf: the fleet tier — throughput and tail latency of single-row
//! INT8/INT4 `mlp3` infer requests through the consistent-hash router,
//! 1 replica vs 3 replicas, plus the latency cost of losing a replica
//! mid-load.
//!
//! Scenarios:
//!
//! * `fleet1` / `fleet3` — the same client load (concurrency 32 in full
//!   runs) against a router fronting 1 vs 3 pool-server replicas, two
//!   routing keys (mlp3 w8a8 / w4a4) spread over the ring.  The
//!   `fleet_speedup` headline is the throughput ratio.
//! * **failover** — a 3-replica fleet where one replica is shut down
//!   mid-load: every request must still be answered (transport failures
//!   retry on the next ring candidate), and `failover_p99_ms` records
//!   the tail latency including the failover spike.
//!
//! `BENCH_SMOKE=1` runs a bounded subset (CI-sized) — either way the
//! numbers land in `bench_results/BENCH_fleet.json`.

use lapq::benchkit::{f3, Table};
use lapq::config::{BitSpec, ExperimentConfig, FleetCfg, Method, ServeCfg};
use lapq::runtime::int::kernels::{active_kernel_name, KernelChoice};
use lapq::runtime::EngineHandle;
use lapq::serve::{PoolHandle, PoolServer, Router, RouterHandle};
use lapq::util::json::Json;
use lapq::util::stats;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

fn infer_req(key: &str, row: &[f32]) -> String {
    Json::obj(vec![
        ("cmd", Json::Str("infer".into())),
        ("key", Json::Str(key.into())),
        ("x", Json::Arr(vec![Json::arr_f32(row)])),
    ])
    .dump()
}

/// One pool-server replica running on its own thread.
struct Cell {
    addr: SocketAddr,
    handle: PoolHandle,
    thread: std::thread::JoinHandle<lapq::Result<()>>,
}

impl Cell {
    fn stop(self) {
        self.handle.shutdown();
        self.thread.join().expect("replica thread").expect("replica serve");
    }
}

/// Start `n` replicas, each preloading the same packed artifacts
/// (deterministic configs → bit-identical models on every cell).
fn start_fleet(
    eng: &EngineHandle,
    n: usize,
    packs: &[ExperimentConfig],
) -> lapq::Result<(Vec<Cell>, Vec<String>)> {
    let scfg = ServeCfg {
        workers: 8,
        batch_window_ms: 0.5,
        max_batch: 32,
        queue_bound: 256,
        registry_cap: 4,
        ..Default::default()
    };
    let mut cells = Vec::with_capacity(n);
    let mut keys = Vec::new();
    for _ in 0..n {
        let server = PoolServer::bind("127.0.0.1:0", eng.clone(), scfg.clone())?;
        keys = server.preload(packs)?;
        let addr = server.addr;
        let handle = server.shutdown_handle();
        let thread = std::thread::spawn(move || server.serve(usize::MAX));
        cells.push(Cell { addr, handle, thread });
    }
    Ok((cells, keys))
}

fn start_router(
    cells: &[Cell],
) -> lapq::Result<(SocketAddr, RouterHandle, std::thread::JoinHandle<lapq::Result<()>>)> {
    let fcfg = FleetCfg {
        replicas: cells.iter().map(|c| c.addr.to_string()).collect(),
        vnodes: 64,
        ping_interval_ms: 100,
        fail_threshold: 2,
        eject_ms: 2000,
    };
    let router = Router::bind("127.0.0.1:0", &fcfg)?;
    let addr = router.addr;
    let handle = router.shutdown_handle();
    let thread = std::thread::spawn(move || router.serve(usize::MAX));
    Ok((addr, handle, thread))
}

/// `clients` persistent connections through `addr`, each issuing `reqs`
/// sequential single-row infer requests (client `ci` targets
/// `keys[ci % len]`).  Returns (throughput req/s, latencies s).
fn run_load(addr: SocketAddr, keys: &[String], clients: usize, reqs: usize) -> (f64, Vec<f32>) {
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for ci in 0..clients {
        let key = keys[ci % keys.len()].clone();
        handles.push(std::thread::spawn(move || {
            let row: Vec<f32> =
                (0..64).map(|j| ((ci * 31 + j * 7) % 23) as f32 * 0.04 - 0.4).collect();
            let stream = TcpStream::connect(addr).expect("connect");
            let mut w = stream.try_clone().expect("clone");
            let mut r = BufReader::new(stream);
            let req = infer_req(&key, &row);
            let mut lat = Vec::with_capacity(reqs);
            let mut line = String::new();
            for _ in 0..reqs {
                let t = Instant::now();
                w.write_all(req.as_bytes()).expect("write");
                w.write_all(b"\n").expect("write");
                w.flush().expect("flush");
                line.clear();
                r.read_line(&mut line).expect("read");
                lat.push(t.elapsed().as_secs_f64() as f32);
                let resp = line.parse::<Json>().expect("json response");
                assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
            }
            lat
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    ((clients * reqs) as f64 / wall, all)
}

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let smoke_var = std::env::var("BENCH_SMOKE");
    let smoke = matches!(smoke_var.as_deref(), Ok(v) if !v.is_empty() && v != "0");
    let conc = if smoke { 8 } else { 32 };
    let reqs = if smoke { 20 } else { 100 };

    // Two routing keys spread over the ring: the same mlp3 at w8a8 and
    // w4a4 (both cheap to pack, deterministic across replicas).
    let pack8 = ExperimentConfig {
        model: "mlp3".into(),
        train_steps: if smoke { 40 } else { 120 },
        lr: 0.1,
        val_size: 512,
        bits: BitSpec::new(8, 8),
        method: Method::Mmse,
        ..Default::default()
    };
    let pack4 = ExperimentConfig { bits: BitSpec::new(4, 4), ..pack8.clone() };
    let packs = [pack8, pack4];
    let eng = EngineHandle::start_default()?;

    let mut table = Table::new(
        "fleet tier: routed throughput + tail latency (INT8/INT4 mlp3, 1-row requests)",
        &["fleet", "conc", "req/s", "p50 ms", "p95 ms", "p99 ms"],
    );
    let mut sizes_json = Vec::new();
    let mut rps_by_n = Vec::new();
    for n in [1usize, 3] {
        let (cells, keys) = start_fleet(&eng, n, &packs)?;
        let (raddr, rhandle, rthread) = start_router(&cells)?;
        let (rps, lat) = run_load(raddr, &keys, conc, reqs);
        rhandle.shutdown();
        rthread.join().expect("router thread")?;
        for c in cells {
            c.stop();
        }
        let p50 = stats::percentile(&lat, 50.0) as f64 * 1e3;
        let p95 = stats::percentile(&lat, 95.0) as f64 * 1e3;
        let p99 = stats::percentile(&lat, 99.0) as f64 * 1e3;
        table.row(&[
            format!("fleet{n}"),
            conc.to_string(),
            format!("{rps:.0}"),
            f3(p50),
            f3(p95),
            f3(p99),
        ]);
        rps_by_n.push(rps);
        sizes_json.push(Json::obj(vec![
            ("replicas", Json::Num(n as f64)),
            ("concurrency", Json::Num(conc as f64)),
            ("requests", Json::Num((conc * reqs) as f64)),
            ("throughput_rps", Json::Num(rps)),
            ("p50_ms", Json::Num(p50)),
            ("p95_ms", Json::Num(p95)),
            ("p99_ms", Json::Num(p99)),
        ]));
    }
    table.print();
    let fleet_speedup = rps_by_n[1] / rps_by_n[0].max(1e-9);
    println!(
        "\nconcurrency {conc}: fleet3 {:.0} req/s vs fleet1 {:.0} req/s ({fleet_speedup:.2}x)",
        rps_by_n[1], rps_by_n[0]
    );

    // -- failover under load ------------------------------------------------
    // 3 replicas, same load; one replica is shut down once the load is
    // in flight.  Every request must still be answered (the router
    // retries transport failures on the next ring candidate); the p99
    // includes the failover spike.
    let (mut cells, keys) = start_fleet(&eng, 3, &packs)?;
    let (raddr, rhandle, rthread) = start_router(&cells)?;
    let killer = {
        let victim = cells.remove(0);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(if smoke { 100 } else { 300 }));
            victim.stop();
        })
    };
    let (failover_rps, lat) = run_load(raddr, &keys, conc, reqs);
    killer.join().expect("killer thread");
    rhandle.shutdown();
    rthread.join().expect("router thread")?;
    for c in cells {
        c.stop();
    }
    let failover_p50_ms = stats::percentile(&lat, 50.0) as f64 * 1e3;
    let failover_p99_ms = stats::percentile(&lat, 99.0) as f64 * 1e3;
    println!(
        "failover (1 of 3 replicas killed mid-load): {failover_rps:.0} req/s, \
         p50 {failover_p50_ms:.3} ms, p99 {failover_p99_ms:.3} ms, all {} requests answered",
        conc * reqs
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_fleet".into())),
        ("smoke", Json::Bool(smoke)),
        ("model", Json::Str("mlp3".into())),
        ("kernel", Json::Str(active_kernel_name(KernelChoice::Auto).into())),
        ("concurrency", Json::Num(conc as f64)),
        ("requests_per_client", Json::Num(reqs as f64)),
        ("fleets", Json::Arr(sizes_json)),
        ("fleet1_rps", Json::Num(rps_by_n[0])),
        ("fleet3_rps", Json::Num(rps_by_n[1])),
        ("fleet_speedup", Json::Num(fleet_speedup)),
        ("failover_rps", Json::Num(failover_rps)),
        ("failover_p50_ms", Json::Num(failover_p50_ms)),
        ("failover_p99_ms", Json::Num(failover_p99_ms)),
    ]);
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("BENCH_fleet.json");
    std::fs::write(&path, report.dump())?;
    println!("[json] wrote {path:?}");
    Ok(())
}
