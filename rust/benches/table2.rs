//! Table 2: NCF-1B stand-in — hit-rate@10 under LAPQ vs MMSE at
//! W/A ∈ {32/8, 8/32, 8/8}.  Paper shape: MMSE collapses even at 8 bits
//! on the recommender while LAPQ stays within ~0.5% of FP32.

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::scheduler::Scheduler;
use lapq::runtime::EngineHandle;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let mut sched = Scheduler::new();

    for (w, a) in [(32u32, 8u32), (8, 32), (8, 8)] {
        for method in [Method::Lapq, Method::Mmse] {
            let mut cfg = ExperimentConfig::default();
            cfg.model = "ncf".into();
            cfg.train_steps = 300;
            cfg.lr = 0.5;
            cfg.calib_size = 8192;
            cfg.val_size = 2048;
            cfg.bits = BitSpec::new(w, a);
            cfg.method = method;
            cfg.lapq.joint.max_evals = 60;
            cfg.lapq.joint.iters = 1;
            sched.push(cfg);
        }
    }
    sched.run_all(&mut runner)?;
    let t = sched.summary_table("Table 2 — NCF-1B stand-in hit-rate@10");
    t.print();
    let _ = t.write_csv("table2.csv");
    if !sched.failures.is_empty() {
        anyhow::bail!("{} jobs failed", sched.failures.len());
    }
    Ok(())
}
