//! Fig. 5: the loss is locally quadratic around the LAPQ optimum Δ* —
//! sample L(Δ* + t·u) along directions u and fit a quadratic in t,
//! reporting R².  Paper shape: high R² near Δ*, both along a random
//! direction and along the p-trajectory.

use lapq::benchkit::Table;
use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::objective::{grids, CalibObjective, LayerMask};
use lapq::lapq::stages::layerwise_deltas;
use lapq::lapq::{Calibrator, NullObserver};
use lapq::optim::quadfit::fit_quadratic;
use lapq::runtime::EngineHandle;
use lapq::util::rng::Pcg32;

fn main() -> lapq::Result<()> {
    lapq::util::logging::init();
    let eng = EngineHandle::start_default()?;
    let mut runner = Runner::new(eng);
    let spec = runner.eng.manifest().model("cnn6")?.clone();

    let mut cfg = ExperimentConfig::default();
    cfg.model = "cnn6".into();
    cfg.train_steps = 300;
    cfg.bits = BitSpec::new(4, 4);
    cfg.method = Method::Lapq;
    cfg.val_size = 512;
    cfg.lapq.joint.max_evals = 60;
    cfg.lapq.joint.iters = 1;
    cfg.lapq.bias_correction = false;

    let (sess, _val, calib) = runner.session_with_calib(&cfg)?;
    let cal = Calibrator::from_config(&cfg);
    let outcome = cal.run(&runner.eng, sess, &spec, &cfg, &calib, &mut NullObserver)?;
    let dw_star: Vec<f32> = outcome.quant.dw.clone();
    let da_star: Vec<f32> = outcome.quant.da.clone();

    let mask = LayerMask::all(spec.n_quant_layers(), cfg.bits).exclude_first_last(&[]);
    let (qmw, qma) = grids(&spec, cfg.bits);
    let mut obj = CalibObjective::new(
        &runner.eng,
        sess,
        calib.loss_batches.clone(),
        mask.clone(),
        qmw,
        qma,
    );

    let mut t = Table::new(
        "Fig. 5 — quadratic fit of L along directions through Δ* (cnn6, 4/4)",
        &["direction", "R²", "a (curv)", "min loss"],
    );

    // (a) random perturbation directions in Δ-space
    let mut rng = Pcg32::seeded(7);
    for k in 0..3 {
        let dir_w: Vec<f32> = dw_star.iter().map(|&d| d * rng.normal() * 0.12).collect();
        let dir_a: Vec<f32> = da_star.iter().map(|&d| d * rng.normal() * 0.12).collect();
        let ts: Vec<f64> = (-4..=4).map(|i| i as f64 / 4.0).collect();
        let mut ys = Vec::new();
        for &tv in &ts {
            let dw: Vec<f32> =
                dw_star.iter().zip(&dir_w).map(|(&d, &u)| (d + tv as f32 * u).max(1e-6)).collect();
            let da: Vec<f32> =
                da_star.iter().zip(&dir_a).map(|(&d, &u)| (d + tv as f32 * u).max(1e-6)).collect();
            ys.push(obj.loss(&dw, &da)?);
        }
        if let Some(q) = fit_quadratic(&ts, &ys) {
            t.row(&[
                format!("random-{k}"),
                format!("{:.3}", q.r2),
                format!("{:.4}", q.a),
                format!("{:.4}", ys.iter().cloned().fold(f64::INFINITY, f64::min)),
            ]);
        }
    }

    // (b) along the p-trajectory (Fig. 5b): loss of Δ_p as a function of p
    let ps: Vec<f64> = vec![1.5, 2.0, 2.5, 3.0, 3.5, 4.0];
    let mut ys = Vec::new();
    for &p in &ps {
        let (dw, da) = layerwise_deltas(&calib, &mask, &obj.qmw.clone(), &obj.qma.clone(), p as f32);
        ys.push(obj.loss(&dw, &da)?);
    }
    if let Some(q) = fit_quadratic(&ps, &ys) {
        t.row(&[
            "p-trajectory".into(),
            format!("{:.3}", q.r2),
            format!("{:.4}", q.a),
            format!("{:.4}", ys.iter().cloned().fold(f64::INFINITY, f64::min)),
        ]);
    }
    t.print();
    let _ = t.write_csv("fig5.csv");
    Ok(())
}
