//! Live-socket tests for the bin1 binary wire dialect: handshake
//! negotiation, JSON-vs-binary bit-identity of infer replies across
//! both servers (pool and blocking), and the hard input bounds —
//! oversized lines / frames and CRC corruption all get typed JSON
//! replies before the connection closes.

use lapq::config::{BitSpec, ExperimentConfig, Method, ServeCfg};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::service::Service;
use lapq::proto::wire::Client;
use lapq::proto::{frame, InferRequest, Request, MAX_FRAME_BYTES, MAX_LINE_BYTES};
use lapq::runtime::EngineHandle;
use lapq::serve::PoolServer;
use lapq::tensor::HostTensor;
use lapq::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn fast_pack_cfg() -> ExperimentConfig {
    ExperimentConfig {
        model: "mlp3".into(),
        train_steps: 40,
        lr: 0.1,
        val_size: 512,
        bits: BitSpec::new(8, 8),
        method: Method::Mmse,
        ..Default::default()
    }
}

/// The logits of a JSON infer response as raw f32 bit patterns (JSON
/// floats are shortest-roundtrip, so the text recovers the exact bits).
fn logits_bits(resp: &Json) -> Vec<u32> {
    resp.req("result")
        .req("logits")
        .as_arr()
        .unwrap()
        .iter()
        .flat_map(|row| {
            row.as_arr().unwrap().iter().map(|v| (v.as_f64().unwrap() as f32).to_bits())
        })
        .collect()
}

fn predictions(resp: &Json) -> Vec<i32> {
    resp.req("result")
        .req("predictions")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect()
}

/// The headline contract: the same infer request served over (pool,
/// blocking) x (JSON, bin1) produces the same logits down to the f32
/// bit pattern, and the same predictions.
#[test]
fn bin1_and_json_infer_are_bit_identical_across_servers() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let scfg = ServeCfg {
        workers: 2,
        batch_window_ms: 0.0,
        max_batch: 4,
        queue_bound: 16,
        registry_cap: 4,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng.clone(), scfg).unwrap();
    let key = server.preload(std::slice::from_ref(&fast_pack_cfg())).unwrap().remove(0);
    let registry = server.registry();
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(2).unwrap());

    let data: Vec<f32> = (0..128).map(|j| ((j * 31) % 17) as f32 * 0.125 - 1.0).collect();
    let ir = InferRequest { key: key.clone(), inputs: vec![HostTensor::f32(vec![2, 64], data)] };
    let req = Request::Infer(ir.clone());

    // JSON over the pool
    let mut jc = Client::connect(&addr).unwrap();
    let jresp = jc.call(&req).unwrap();
    assert_eq!(jresp.req("ok").as_bool(), Some(true), "{jresp:?}");
    let json_bits = logits_bits(&jresp);
    let json_preds = predictions(&jresp);
    drop(jc);

    // bin1 over the pool: same connection loop, framed reply
    let mut bc = Client::connect(&addr).unwrap();
    bc.hello_bin1().unwrap();
    let (reply, preds) = bc.infer_bin(&ir).unwrap();
    assert_eq!(reply.key, key);
    assert_eq!(reply.rows, 2);
    let bin_bits: Vec<u32> = reply.logits.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(bin_bits, json_bits, "bin1 logits must be the JSON logits, bit for bit");
    assert_eq!(preds, json_preds, "server-computed predictions agree across encodings");
    drop(bc);
    pool.join().unwrap();

    // The blocking service over the same packed artifact speaks both
    // dialects too (the connection loop is shared, not duplicated).
    let seq = Service::bind("127.0.0.1:0").unwrap();
    let seq_addr = seq.addr;
    let seq_thread = std::thread::spawn(move || {
        let mut runner = Runner::with_registry(eng, registry);
        seq.serve(&mut runner, 2).unwrap();
    });

    let mut sc = Client::connect(&seq_addr).unwrap();
    let sresp = sc.call(&req).unwrap();
    assert_eq!(sresp.req("ok").as_bool(), Some(true), "{sresp:?}");
    assert_eq!(logits_bits(&sresp), json_bits, "blocking JSON matches pool JSON");
    drop(sc);

    let mut sb = Client::connect(&seq_addr).unwrap();
    sb.hello_bin1().unwrap();
    let (sreply, spreds) = sb.infer_bin(&ir).unwrap();
    let sbits: Vec<u32> = sreply.logits.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(sbits, json_bits, "blocking bin1 matches pool JSON");
    assert_eq!(spreds, json_preds);
    drop(sb);
    seq_thread.join().unwrap();
}

/// Frames are gated behind the hello/bin1 handshake; corruption is
/// caught by the CRC and answered with a JSON error (errors are never
/// framed) before the stream — which cannot be resynced — is closed.
#[test]
fn frames_require_handshake_and_corruption_closes() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let scfg = ServeCfg {
        workers: 1,
        batch_window_ms: 0.0,
        max_batch: 1,
        queue_bound: 4,
        registry_cap: 2,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng, scfg).unwrap();
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(2).unwrap());

    let ir = InferRequest {
        key: "nope".into(),
        inputs: vec![HostTensor::f32(vec![1, 4], vec![0.5; 4])],
    };
    let mut frame_bytes = Vec::new();
    frame::encode_infer_request(&ir, &mut frame_bytes);

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    let mut roundtrip = |w: &mut TcpStream, r: &mut BufReader<TcpStream>, bytes: &[u8]| -> Json {
        w.write_all(bytes).unwrap();
        w.flush().unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        line.parse::<Json>().expect("structured reply")
    };

    // a frame before the handshake is refused, connection keeps serving
    let j = roundtrip(&mut w, &mut r, &frame_bytes);
    assert_eq!(j.req("ok").as_bool(), Some(false));
    assert!(j.req("error").as_str().unwrap().contains("handshake"), "{j:?}");

    // unknown dialects are refused, the connection stays JSON
    let j = roundtrip(&mut w, &mut r, b"{\"cmd\":\"hello\",\"wire\":\"bogus\"}\n");
    assert!(j.req("error").as_str().unwrap().contains("unknown wire"), "{j:?}");

    // a good handshake upgrades the same connection
    let j = roundtrip(&mut w, &mut r, b"{\"cmd\":\"hello\",\"wire\":\"bin1\"}\n");
    assert_eq!(j.req("wire").as_str(), Some("bin1"), "{j:?}");

    // one flipped payload bit: the CRC catches it, the reply is a JSON
    // error, and the connection is closed (no resync on a binary stream)
    let mut bad = frame_bytes.clone();
    let n = bad.len();
    bad[n - frame::CRC_LEN - 1] ^= 0x01;
    let j = roundtrip(&mut w, &mut r, &bad);
    assert_eq!(j.req("ok").as_bool(), Some(false));
    assert!(j.req("error").as_str().unwrap().contains("crc"), "{j:?}");
    let mut rest = String::new();
    assert_eq!(r.read_line(&mut rest).unwrap(), 0, "corrupt frame must close the connection");
    drop(w);

    // fresh connection: after the handshake a *valid* frame for a
    // missing model comes back as a JSON error line, and the same
    // connection still answers pings
    let mut c = Client::connect(&addr).unwrap();
    c.hello_bin1().unwrap();
    let err = c.infer_bin(&ir).expect_err("missing model must fail");
    assert!(format!("{err:#}").contains("no packed model"), "{err:#}");
    let pong = c.call(&Request::Ping).unwrap();
    assert_eq!(pong.req("pong").as_bool(), Some(true));
    drop(c);
    pool.join().unwrap();
}

/// Input bounds: a line past `MAX_LINE_BYTES` or a frame advertising
/// more than `MAX_FRAME_BYTES` gets the typed `too_large` reply, then
/// the connection closes (the oversized input is never buffered whole).
#[test]
fn oversized_inputs_get_typed_replies_then_close() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let scfg = ServeCfg {
        workers: 1,
        batch_window_ms: 0.0,
        max_batch: 1,
        queue_bound: 4,
        registry_cap: 2,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng, scfg).unwrap();
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(2).unwrap());

    // an endless line: the server answers as soon as the cap is crossed
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let chunk = vec![b'x'; 8 * 1024];
    let mut sent = 0usize;
    while sent <= MAX_LINE_BYTES + chunk.len() {
        // the server may close mid-send — that's the expected outcome
        if w.write_all(&chunk).is_err() {
            break;
        }
        sent += chunk.len();
    }
    let _ = w.flush();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let j: Json = line.parse().expect("typed too_large reply");
    assert_eq!(j.req("error").as_str(), Some("too_large"), "{j:?}");
    assert_eq!(j.req("limit_bytes").as_f64(), Some(MAX_LINE_BYTES as f64), "{j:?}");
    line.clear();
    assert_eq!(r.read_line(&mut line).unwrap(), 0, "oversized line closes the connection");
    drop(w);

    // a frame header promising a payload past the frame cap: refused
    // from the 8 header bytes alone
    let s2 = TcpStream::connect(addr).unwrap();
    s2.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut w2 = s2.try_clone().unwrap();
    let mut r2 = BufReader::new(s2);
    let mut hdr = vec![frame::MARKER, frame::MAGIC2, frame::VERSION, frame::KIND_INFER_REQ];
    hdr.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
    w2.write_all(&hdr).unwrap();
    w2.flush().unwrap();
    let mut line2 = String::new();
    r2.read_line(&mut line2).unwrap();
    let j: Json = line2.parse().expect("typed too_large reply");
    assert_eq!(j.req("error").as_str(), Some("too_large"), "{j:?}");
    assert_eq!(j.req("limit_bytes").as_f64(), Some(MAX_FRAME_BYTES as f64), "{j:?}");
    line2.clear();
    assert_eq!(r2.read_line(&mut line2).unwrap(), 0, "oversized frame closes the connection");
    pool.join().unwrap();
}
