//! End-to-end tests for the fleet tier.
//!
//! 1. Registry disk spill: a model evicted from a tiny sharded registry
//!    is transparently reloaded on the next infer, bit-identical.
//! 2. The consistent-hash router over two *child-process* pool replicas
//!    (spawned through the real CLI) answers byte-identical to a single
//!    pool server — before and after one replica is killed.
//! 3. An overload shed from the key's owning replica is retried on the
//!    next ring candidate instead of surfacing to the client.

use lapq::config::{BitSpec, ExperimentConfig, FleetCfg, Method};
use lapq::coordinator::jobs::Runner;
use lapq::proto::{InferRequest, Request};
use lapq::runtime::int::PackOpts;
use lapq::runtime::EngineHandle;
use lapq::serve::fleet::Ring;
use lapq::serve::{ModelRegistry, Router};
use lapq::tensor::HostTensor;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn fast_cfg(method: Method) -> ExperimentConfig {
    ExperimentConfig {
        model: "mlp3".into(),
        train_steps: 40,
        lr: 0.1,
        val_size: 512,
        bits: BitSpec::new(8, 8),
        method,
        ..Default::default()
    }
}

fn inputs_for(t: usize) -> Vec<HostTensor> {
    let data: Vec<f32> =
        (0..2 * 64).map(|j| ((j * 31 + t * 7) % 17) as f32 * 0.125 - 1.0).collect();
    vec![HostTensor::f32(vec![2, 64], data)]
}

fn infer_line(key: &str, t: usize) -> String {
    let ir = InferRequest { key: key.into(), inputs: inputs_for(t) };
    let mut line = String::new();
    Request::Infer(ir).write_json(&mut line);
    line
}

/// Zero the wall-clock `"seconds"` value in a JSON reply so the rest of
/// the response can be compared byte for byte across servers.
fn normalize_seconds(line: &str) -> String {
    match line.find("\"seconds\":") {
        None => line.to_string(),
        Some(i) => {
            let start = i + "\"seconds\":".len();
            let end = line[start..]
                .find([',', '}'])
                .map(|j| start + j)
                .expect("seconds value is delimited");
            format!("{}0{}", &line[..start], &line[end..])
        }
    }
}

// ---------------------------------------------------------------- spill

#[test]
fn evicted_model_reloads_from_spill_bit_identical() {
    let dir = std::env::temp_dir().join(format!("lapq_fleet_spill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let eng = EngineHandle::start_default().expect("engine boots");
    // cap 1 over 2 shards: the second pack must evict (and spill) the
    // first, wherever the two keys hash.
    let registry = Arc::new(ModelRegistry::with_options(1, 2, Some(dir.clone())));
    let mut runner = Runner::with_registry(eng, registry.clone());

    let cfg_a = fast_cfg(Method::Mmse);
    let key_a = Runner::pack_key(&cfg_a);
    runner.pack(&cfg_a, &PackOpts::default()).expect("pack a");
    let before = runner.infer(&key_a, &inputs_for(0)).expect("infer before eviction");

    let cfg_b = fast_cfg(Method::MinMax);
    runner.pack(&cfg_b, &PackOpts::default()).expect("pack b");
    let stats = registry.stats();
    assert!(stats.evictions >= 1, "cap 1 must evict: {stats:?}");
    assert!(stats.spills >= 1, "eviction must spill to disk: {stats:?}");

    // The evicted key infers again: transparently reloaded, same bits.
    let after = runner.infer(&key_a, &inputs_for(0)).expect("infer after eviction reloads");
    let bits = |r: &lapq::coordinator::jobs::InferReply| -> Vec<u32> {
        r.logits.data.iter().map(|v| v.to_bits()).collect()
    };
    assert_eq!(bits(&before), bits(&after), "reloaded logits are bit-identical");
    assert!(registry.stats().reloads >= 1, "reload counter bumps: {:?}", registry.stats());

    // A key that was never packed still fails — with the typed token.
    let err = runner.infer("ghost:w8a8:MinMax", &inputs_for(0)).expect_err("ghost key");
    assert!(lapq::proto::is_model_not_packed(&err), "typed miss, got: {err:#}");

    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------------- child fleet

/// A pool-server replica spawned through the real CLI, killed on drop.
struct Replica {
    child: Child,
    addr: SocketAddr,
    key: String,
}

impl Drop for Replica {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_replica() -> Replica {
    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--preload",
            "mlp3",
            "--workers",
            "2",
            "-s",
            "train_steps=40",
            "-s",
            "lr=0.1",
            "-s",
            "val_size=512",
            "-s",
            "bits_w=8",
            "-s",
            "bits_a=8",
            "-s",
            "method=mmse",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn replica (CARGO_BIN_EXE_repro)");
    let stdout = child.stdout.take().expect("stdout piped");
    let mut lines = BufReader::new(stdout).lines();
    let mut key = String::new();
    let addr = loop {
        let line = lines
            .next()
            .expect("replica exited before 'serving on'")
            .expect("replica stdout read");
        if let Some(rest) = line.strip_prefix("preloaded: ") {
            key = rest.trim().to_string();
        } else if let Some(rest) = line.strip_prefix("serving on ") {
            let tok = rest.split_whitespace().next().expect("addr token");
            break tok.parse().expect("replica addr parses");
        }
    };
    assert!(!key.is_empty(), "replica printed no preloaded key");
    // Drain the rest of stdout forever so the child can never block on
    // a full pipe.
    std::thread::spawn(move || for _ in lines {});
    Replica { child, addr, key }
}

/// A persistent raw JSON-lines connection (requests and responses are
/// exact lines; responses compared byte-for-byte).
struct Conn {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Conn {
    fn connect(addr: &SocketAddr) -> Conn {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        s.set_write_timeout(Some(Duration::from_secs(120))).unwrap();
        let w = s.try_clone().unwrap();
        Conn { w, r: BufReader::new(s) }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.w.write_all(line.as_bytes()).unwrap();
        self.w.write_all(b"\n").unwrap();
        self.w.flush().unwrap();
        let mut out = String::new();
        self.r.read_line(&mut out).expect("read response line");
        out
    }
}

fn oneshot(addr: &SocketAddr, line: &str) -> String {
    Conn::connect(addr).roundtrip(line)
}

#[test]
fn router_matches_single_pool_and_fails_over() {
    let mut reps = vec![spawn_replica(), spawn_replica()];
    assert_eq!(reps[0].key, reps[1].key, "replicas pack deterministically");
    let key = reps[0].key.clone();

    let fcfg = FleetCfg {
        replicas: vec![reps[0].addr.to_string(), reps[1].addr.to_string()],
        vnodes: 64,
        ping_interval_ms: 100,
        fail_threshold: 2,
        eject_ms: 500,
    };
    let router = Router::bind("127.0.0.1:0", &fcfg).expect("router binds");
    let raddr = router.addr;
    let handle = router.shutdown_handle();
    let rt = std::thread::spawn(move || router.serve(usize::MAX).unwrap());

    let mut through = Conn::connect(&raddr);

    // ping and models are the router's own answers
    let pong = through.roundtrip("{\"cmd\":\"ping\"}");
    assert!(pong.contains("\"ok\":true"), "{pong}");
    let models = through.roundtrip("{\"cmd\":\"models\"}");
    assert!(models.contains(&key), "merged models lists the pack: {models}");

    // full fleet: every routed infer is byte-identical to one pool
    for t in 0..4 {
        let line = infer_line(&key, t);
        let got = through.roundtrip(&line);
        let want = oneshot(&reps[0].addr, &line);
        assert!(got.contains("\"ok\":true"), "routed infer failed: {got}");
        assert_eq!(normalize_seconds(&got), normalize_seconds(&want), "request {t}");
    }

    // the typed registry miss relays through untouched
    let ghost = infer_line("ghost:w8a8:MinMax", 0);
    let miss = through.roundtrip(&ghost);
    assert!(
        miss.starts_with("{\"error\":\"model_not_packed\""),
        "typed miss through the router: {miss}"
    );

    // Kill the key's *owning* replica: the same persistent client
    // connection (with its cached upstream) must fail over and stay
    // byte-identical to the survivor.
    let owner = Ring::new(2, fcfg.vnodes).candidates(&key)[0];
    let survivor = reps[1 - owner].addr;
    drop(reps.remove(owner));
    for t in 4..10 {
        let line = infer_line(&key, t);
        let got = through.roundtrip(&line);
        let want = oneshot(&survivor, &line);
        assert!(got.contains("\"ok\":true"), "post-kill routed infer failed: {got}");
        assert_eq!(normalize_seconds(&got), normalize_seconds(&want), "request {t} after kill");
    }

    drop(through);
    handle.shutdown();
    rt.join().unwrap();
}

// ------------------------------------------------------------- sheds

/// A fake replica that answers pings but sheds every other request,
/// counting the sheds it served.
fn spawn_shedding_replica(shed_count: Arc<AtomicUsize>) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind fake replica");
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let shed_count = shed_count.clone();
            std::thread::spawn(move || {
                let mut w = stream.try_clone().unwrap();
                let r = BufReader::new(stream);
                for line in r.lines() {
                    let Ok(line) = line else { break };
                    let reply = if line.contains("\"cmd\":\"ping\"") {
                        "{\"ok\":true,\"pong\":true}\n".to_string()
                    } else {
                        shed_count.fetch_add(1, Ordering::SeqCst);
                        "{\"error\":\"overloaded\",\"ok\":false,\"retry_after_ms\":5}\n".into()
                    };
                    if w.write_all(reply.as_bytes()).and_then(|_| w.flush()).is_err() {
                        break;
                    }
                }
            });
        }
    });
    addr
}

#[test]
fn overload_shed_retries_on_the_next_ring_candidate() {
    let real = spawn_replica();
    let key = real.key.clone();
    let sheds = Arc::new(AtomicUsize::new(0));
    let fake = spawn_shedding_replica(sheds.clone());

    // Place the always-shedding fake at the key's owning ring slot so
    // the router *must* hit it first and retry onto the real replica.
    let owner = Ring::new(2, 64).candidates(&key)[0];
    let mut replicas = vec![String::new(), String::new()];
    replicas[owner] = fake.to_string();
    replicas[1 - owner] = real.addr.to_string();

    let fcfg = FleetCfg {
        replicas,
        vnodes: 64,
        ping_interval_ms: 100,
        fail_threshold: 3,
        eject_ms: 1000,
    };
    let router = Router::bind("127.0.0.1:0", &fcfg).expect("router binds");
    let raddr = router.addr;
    let handle = router.shutdown_handle();
    let rt = std::thread::spawn(move || router.serve(usize::MAX).unwrap());

    let line = infer_line(&key, 1);
    let got = oneshot(&raddr, &line);
    let want = oneshot(&real.addr, &line);
    assert!(got.contains("\"ok\":true"), "shed must be retried, not surfaced: {got}");
    assert_eq!(normalize_seconds(&got), normalize_seconds(&want), "retried reply matches");
    assert!(sheds.load(Ordering::SeqCst) >= 1, "the owning replica did shed first");

    handle.shutdown();
    rt.join().unwrap();
}
