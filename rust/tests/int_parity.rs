//! Integration: the integer inference engine against the fake-quant CPU
//! backend.
//!
//! With the power-of-two scales `pack` emits, the fake-quant reference's
//! f32 arithmetic is exact wherever the i32 accumulator stays below 2²⁴,
//! so the integer engine must match it **bit-for-bit** on `mlp3` and
//! `ncf` (INT8 and INT4).  `cnn6`'s widest conv can exceed that bound,
//! so its per-layer quantized activations are allowed to differ by one
//! grid step.

use lapq::config::{BitSpec, ExperimentConfig, Method, ServeCfg};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::service::request;
use lapq::data::ncf::SynthNcf;
use lapq::data::vision::SynthVision;
use lapq::quant::{minmax, GridKind};
use lapq::runtime::cpu::{ops, zoo};
use lapq::runtime::int::model::{pack, snap_po2, PackOpts, Payload, QuantizedModel};
use lapq::runtime::int::{ExecMode, InferSession};
use lapq::runtime::{EngineHandle, Manifest, ModelSpec, QuantParams};
use lapq::serve::PoolServer;
use lapq::tensor::init::init_params;
use lapq::tensor::HostTensor;
use lapq::util::json::Json;

/// Per-layer power-of-two grids from the actual weight/activation ranges
/// (min-max, snapped) — what a calibration-then-pack run would produce.
fn po2_quant(
    spec: &ModelSpec,
    params: &[HostTensor],
    acts_batch: &[HostTensor],
    wbits: u32,
    abits: u32,
) -> QuantParams {
    po2_quant_mixed(spec, params, acts_batch, &vec![wbits; spec.n_quant_layers()], abits)
}

/// Same, but with an explicit per-layer weight width — what a
/// mixed-precision bit plan feeds the packer.
fn po2_quant_mixed(
    spec: &ModelSpec,
    params: &[HostTensor],
    acts_batch: &[HostTensor],
    wbits: &[u32],
    abits: u32,
) -> QuantParams {
    let acts = zoo::acts(spec, params, acts_batch).expect("acts");
    let n = spec.n_quant_layers();
    assert_eq!(wbits.len(), n);
    let mut q = QuantParams {
        dw: vec![0.0; n],
        qmw: wbits.iter().map(|&b| GridKind::Signed.qmax(b)).collect(),
        da: vec![0.0; n],
        qma: vec![0.0; n],
    };
    for (i, ql) in spec.quant_layers.iter().enumerate() {
        let w = params[ql.weight_param].f();
        q.dw[i] = snap_po2(minmax::minmax_delta(w, q.qmw[i], GridKind::Signed));
        let kind = GridKind::from_signed(ql.act_signed);
        q.qma[i] = kind.qmax(abits);
        q.da[i] = snap_po2(minmax::minmax_delta(acts[i].f(), q.qma[i], kind));
    }
    q
}

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lapq_int_{tag}_{}", std::process::id()))
}

#[test]
fn int8_mlp3_bit_exact_with_fake_quant_backend() {
    let manifest = Manifest::builtin();
    let spec = manifest.model("mlp3").unwrap();
    for seed in [1u64, 7, 23] {
        let params = init_params(&spec.params, seed);
        let data = SynthVision::new(seed);
        let (x, y) = data.batch_features(0, 64, 64);
        let q = po2_quant(spec, &params, &[x.clone()], 8, 8);
        let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
        let mut sess = InferSession::new(spec, &qm).unwrap();
        sess.record_taps = true;
        let int_res = sess.infer(&[x.clone()], ExecMode::Int).unwrap();
        let sim_res = sess.infer(&[x.clone()], ExecMode::Simulated).unwrap();
        assert_eq!(int_res.int_layers, 3, "seed {seed}");
        assert_eq!(sim_res.int_layers, 0);

        // per-layer: quantized inputs and outputs bit-for-bit
        assert_eq!(int_res.taps.len(), 3);
        for (ti, si) in int_res.taps.iter().zip(&sim_res.taps) {
            assert_eq!(ti.qx, si.qx, "seed {seed} layer {} quantized inputs", ti.name);
            assert_bits_equal(&ti.y.data, &si.y.data, &format!("seed {seed} layer {}", ti.name));
        }
        assert_bits_equal(&int_res.logits.data, &sim_res.logits.data, "logits");

        // ...and the simulated reference IS the CPU backend's graph: the
        // loss computed from these logits matches `zoo::eval` bitwise.
        let my_loss = ops::softmax_xent(&sim_res.logits, y.i());
        let (ref_loss, _) = zoo::eval(spec, &params, Some(&qm.quant), &[x, y]).unwrap();
        assert_eq!(my_loss.to_bits(), ref_loss.to_bits(), "seed {seed} loss");
    }
}

#[test]
fn int8_cnn6_within_one_grid_step() {
    let manifest = Manifest::builtin();
    let spec = manifest.model("cnn6").unwrap();
    let params = init_params(&spec.params, 5);
    let data = SynthVision::new(5);
    let (x, _) = data.batch(0, 8);
    let q = po2_quant(spec, &params, &[x.clone()], 8, 8);
    let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
    let mut sess = InferSession::new(spec, &qm).unwrap();
    sess.record_taps = true;
    let int_res = sess.infer(&[x.clone()], ExecMode::Int).unwrap();
    let sim_res = sess.infer(&[x], ExecMode::Simulated).unwrap();
    assert_eq!(int_res.int_layers, 6);

    // The widest conv's accumulator can cross 2^24, where the f32
    // reference itself rounds — allow one grid step ("1 ULP of grid").
    for (ti, si) in int_res.taps.iter().zip(&sim_res.taps) {
        assert_eq!(ti.qx.len(), si.qx.len(), "layer {}", ti.name);
        let max_dq = ti.qx.iter().zip(&si.qx).map(|(a, b)| (a - b).abs()).max().unwrap_or(0);
        assert!(max_dq <= 1, "layer {}: quantized inputs differ by {max_dq}", ti.name);
    }
    let scale = sim_res.logits.data.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
    for (a, b) in int_res.logits.data.iter().zip(&sim_res.logits.data) {
        assert!((a - b).abs() <= 1e-3 * scale, "logits {a} vs {b}");
    }
}

#[test]
fn int8_ncf_bit_exact_with_fake_quant_backend() {
    let manifest = Manifest::builtin();
    let spec = manifest.model("ncf").unwrap();
    let params = init_params(&spec.params, 3);
    let data = SynthNcf::new(3, 2000, 1000, 6);
    let (u, items, labels) = data.train_batch(0, 256, 4);
    let q = po2_quant(spec, &params, &[u.clone(), items.clone()], 8, 8);
    let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
    let sess = InferSession::new(spec, &qm).unwrap();
    let int_res = sess.infer(&[u.clone(), items.clone()], ExecMode::Int).unwrap();
    let sim_res = sess.infer(&[u.clone(), items.clone()], ExecMode::Simulated).unwrap();
    assert_eq!(int_res.int_layers, 7); // 4 embeds + 3 dense
    assert_bits_equal(&int_res.logits.data, &sim_res.logits.data, "ncf logits");

    let my_loss = ops::bce_logits(&sim_res.logits, labels.f());
    let (ref_loss, _) = zoo::eval(spec, &params, Some(&qm.quant), &[u, items, labels]).unwrap();
    assert_eq!(my_loss.to_bits(), ref_loss.to_bits(), "ncf loss");
}

#[test]
fn int4_mlp3_artifact_roundtrip_and_parity() {
    let manifest = Manifest::builtin();
    let spec = manifest.model("mlp3").unwrap();
    let params = init_params(&spec.params, 11);
    let data = SynthVision::new(11);
    let (x, _) = data.batch_features(0, 32, 64);
    let q = po2_quant(spec, &params, &[x.clone()], 4, 4);
    let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();

    // serialize through the nibble-packed blob and back
    let dir = tmp_dir("i4");
    qm.save(&dir).unwrap();
    let loaded = QuantizedModel::load(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(loaded, qm);
    for p in &loaded.params {
        if let Payload::Int { bits, q, .. } = &p.payload {
            assert_eq!(*bits, 4, "param {}", p.name);
            assert!(q.iter().all(|&v| (-7..=7).contains(&v)), "param {}", p.name);
        }
    }

    // INT4 accumulators are tiny: bit-exact parity again
    let sess = InferSession::new(spec, &loaded).unwrap();
    let int_res = sess.infer(&[x.clone()], ExecMode::Int).unwrap();
    let sim_res = sess.infer(&[x], ExecMode::Simulated).unwrap();
    assert_eq!(int_res.int_layers, 3);
    assert_bits_equal(&int_res.logits.data, &sim_res.logits.data, "int4 logits");
}

#[test]
fn mixed_w8_w4_mlp3_bit_exact_with_fake_quant_backend() {
    let manifest = Manifest::builtin();
    let spec = manifest.model("mlp3").unwrap();
    for seed in [2u64, 13] {
        let params = init_params(&spec.params, seed);
        let data = SynthVision::new(seed);
        let (x, _) = data.batch_features(0, 32, 64);
        // a hand-written W8/W4 plan: heterogeneous widths in one artifact
        let q = po2_quant_mixed(spec, &params, &[x.clone()], &[8, 4, 8], 8);
        let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
        assert_eq!(qm.wbits(), vec![8, 4, 8], "seed {seed}");

        // the blob round-trips with per-layer widths intact
        let dir = tmp_dir(&format!("mixed{seed}"));
        qm.save(&dir).unwrap();
        let loaded = QuantizedModel::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(loaded, qm, "seed {seed}");
        for p in &loaded.params {
            if let Payload::Int { bits, q, .. } = &p.payload {
                let qmax = GridKind::Signed.qmax(*bits) as i32;
                assert!(
                    q.iter().all(|&v| (-qmax..=qmax).contains(&(v as i32))),
                    "param {} exceeds its {}-bit grid",
                    p.name,
                    bits
                );
            }
        }

        // W8 and W4 accumulators both stay under 2^24 on mlp3: bit-exact
        let sess = InferSession::new(spec, &loaded).unwrap();
        let int_res = sess.infer(&[x.clone()], ExecMode::Int).unwrap();
        let sim_res = sess.infer(&[x], ExecMode::Simulated).unwrap();
        assert_eq!(int_res.int_layers, 3, "seed {seed}");
        assert_bits_equal(&int_res.logits.data, &sim_res.logits.data, "mixed logits");
    }
}

/// The nibble-domain kernel end to end: a mixed ≤4-bit plan on `cnn6`
/// keeps every accumulator far below 2²⁴ (k·7·255 < 2²⁴ up to k ≈ 9395),
/// so unlike INT8 `cnn6` the fake-quant reference is exact and the
/// int4-direct path must match it **bit-for-bit** — through pack, the
/// disk round-trip, an [`InferSession`], and a pool-server `infer` fed
/// one NHWC image as flat `"x"` + `"shape"`.
#[test]
fn int4_direct_cnn6_bit_exact_through_pool_serving() {
    let manifest = Manifest::builtin();
    let spec = manifest.model("cnn6").unwrap();
    let params = init_params(&spec.params, 17);
    let data = SynthVision::new(17);
    let (x, _) = data.batch(0, 2);
    let wbits = [4u32, 2, 4, 4, 2, 4];
    let q = po2_quant_mixed(spec, &params, &[x.clone()], &wbits, 8);
    let qm = pack(spec, &params, &q, None, &PackOpts::default()).unwrap();
    assert_eq!(qm.wbits(), wbits.to_vec());

    let dir = tmp_dir("i4cnn");
    qm.save(&dir).unwrap();
    let loaded = QuantizedModel::load(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(loaded, qm);
    for p in &loaded.params {
        if let Payload::Int { bits, q, .. } = &p.payload {
            assert!(*bits <= 4, "param {} is {} bits", p.name, bits);
            assert!(q.iter().all(|&v| (-7..=7).contains(&v)), "param {}", p.name);
        }
    }

    // every layer routes through the int4-direct kernel (bits ≤ 4), and
    // the result is bit-exact against the fake-quant reference
    let mut sess = InferSession::new(spec, &loaded).unwrap();
    sess.record_taps = true;
    let int_res = sess.infer(&[x.clone()], ExecMode::Int).unwrap();
    let sim_res = sess.infer(&[x.clone()], ExecMode::Simulated).unwrap();
    assert_eq!(int_res.int_layers, 6);
    for (ti, si) in int_res.taps.iter().zip(&sim_res.taps) {
        assert_eq!(ti.qx, si.qx, "layer {} quantized inputs", ti.name);
        assert_bits_equal(&ti.y.data, &si.y.data, &format!("layer {}", ti.name));
    }
    assert_bits_equal(&int_res.logits.data, &sim_res.logits.data, "int4 cnn6 logits");

    // ...and over the wire: one image as flat "x" + "shape" [1,32,32,3]
    let one = data.batch(0, 1).0;
    let want = sess.infer(&[one.clone()], ExecMode::Int).unwrap();
    let eng = EngineHandle::start_default().unwrap();
    let scfg = ServeCfg {
        workers: 2,
        batch_window_ms: 0.0,
        max_batch: 4,
        queue_bound: 16,
        registry_cap: 4,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng, scfg).unwrap();
    server.registry().put("cnn6:int4".to_string(), std::sync::Arc::new(loaded));
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(1).unwrap());
    let reply = request(
        &addr,
        &Json::obj(vec![
            ("cmd", Json::Str("infer".into())),
            ("key", Json::Str("cnn6:int4".into())),
            ("x", Json::arr_f32(one.f())),
            ("shape", Json::Arr([1, 32, 32, 3].iter().map(|&v| Json::Num(v as f64)).collect())),
        ]),
    )
    .unwrap();
    assert_eq!(reply.req("ok").as_bool(), Some(true), "{reply:?}");
    let got: Vec<f32> = reply.req("result").req("logits").as_arr().unwrap()[0]
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|j| j.as_f64().map(|v| v as f32))
        .collect();
    assert_bits_equal(&got, &want.logits.data, "served int4 logits");
    pool.join().unwrap();
}

#[test]
fn runner_pack_infer_roundtrip_int8_lapq() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp3".into();
    cfg.train_steps = 60;
    cfg.lr = 0.1;
    cfg.calib_size = 512;
    cfg.val_size = 1024;
    cfg.bits = BitSpec::new(8, 8);
    cfg.method = Method::Lapq;
    cfg.lapq.joint.max_evals = 120;
    cfg.lapq.joint.iters = 1;

    let (sum, qm) = runner.pack(&cfg, &PackOpts::default()).unwrap();
    assert_eq!(sum.key, Runner::pack_key(&cfg));
    assert!(sum.packed_bytes < sum.f32_bytes, "{} vs {}", sum.packed_bytes, sum.f32_bytes);
    assert!(sum.quant_metric >= sum.fp32_metric - 0.05, "{sum:?}");
    // the calibration's layer mask rode along into the artifact
    assert_eq!(qm.active_w, vec![false, true, false]);

    // serve a batch from the cache with the integer engine
    let data = SynthVision::new(42);
    let (x, _) = data.batch_features(0, 32, 64);
    let reply = runner.infer(&sum.key, &[x.clone()]).unwrap();
    assert_eq!(reply.rows, 32);
    assert_eq!(reply.logits.shape, vec![32, 16]);
    assert_eq!(reply.int_layers, 1); // exclude_first_last leaves fc2

    // bit-for-bit against the fake-quant reference on the same batch
    let spec = runner.eng.manifest().model("mlp3").unwrap().clone();
    let sess = InferSession::new(&spec, &qm).unwrap();
    let sim = sess.infer(&[x.clone()], ExecMode::Simulated).unwrap();
    assert_bits_equal(&reply.logits.data, &sim.logits.data, "served logits");

    // bare model name resolves through the MRU cache; unknown keys error
    assert!(runner.infer("mlp3", &[x]).is_ok());
    assert!(runner.infer("nope", &[HostTensor::zeros(vec![1, 64])]).is_err());
}
