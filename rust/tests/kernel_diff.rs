//! Differential kernel harness: every dispatch tier of the integer
//! GEMM/conv (blocked, SIMD-when-detected, nibble-domain INT4) must be
//! **bit-identical** to the scalar reference loops — the property the
//! whole integer engine's parity story rests on.
//!
//! ~2k seeded generated cases: random (m, k, n) with k not divisible by
//! the pair/panel widths, the m = 1 serving shape, zero-size and
//! single-element inputs, saturating ±127/±128-adjacent values, both
//! `i8` and `u8` activations, and strided conv geometries with ragged
//! channel counts.

use lapq::runtime::int::kernels::pack::{MR, NR};
use lapq::runtime::int::kernels::{
    acc_fits_i32, conv_int_i4_with, conv_int_with, conv_shape, gemm_i4_with, gemm_with,
    KernelChoice, QAct,
};
use lapq::util::rng::Pcg32;

/// The non-reference tiers, each pinned against `Scalar`.  `Simd`
/// silently degrades to `Blocked` on machines without a detected
/// extension — the assertion holds either way.
const TIERS: [KernelChoice; 3] = [KernelChoice::Blocked, KernelChoice::Simd, KernelChoice::Auto];

fn draw_w8(rng: &mut Pcg32, count: usize) -> Vec<i8> {
    (0..count)
        .map(|_| match rng.below(8) {
            // keep the saturating corners hot: full-range i8 weights,
            // including -128 (beyond the symmetric grid, still exact)
            0 => [-128i8, -127, -126, 126, 127][rng.below(5) as usize],
            _ => (rng.below(256) as i32 - 128) as i8,
        })
        .collect()
}

fn draw_w4(rng: &mut Pcg32, count: usize) -> Vec<i8> {
    (0..count)
        .map(|_| match rng.below(8) {
            0 => [-8i8, -7, 7][rng.below(3) as usize],
            _ => (rng.below(16) as i32 - 8) as i8,
        })
        .collect()
}

fn draw_a8(rng: &mut Pcg32, count: usize) -> Vec<i8> {
    (0..count)
        .map(|_| match rng.below(8) {
            0 => [-128i8, -127, 0, 126, 127][rng.below(5) as usize],
            _ => (rng.below(256) as i32 - 128) as i8,
        })
        .collect()
}

fn draw_u8(rng: &mut Pcg32, count: usize) -> Vec<u8> {
    (0..count)
        .map(|_| match rng.below(8) {
            0 => [0u8, 1, 254, 255][rng.below(4) as usize],
            _ => rng.below(256) as u8,
        })
        .collect()
}

/// Shapes that stress the panel geometry: ragged against `MR`/`NR`, odd
/// k (not divisible by the pair width), m = 1 serving rows, zero-size
/// and single-element operands.
fn edge_shapes() -> Vec<(usize, usize, usize)> {
    vec![
        (0, 0, 0),
        (0, 5, 3),
        (2, 0, 7),
        (3, 4, 0),
        (1, 1, 1),
        (1, 17, 1),
        (1, 64, NR),
        (1, 63, NR + 1),
        (MR, 2, NR),
        (MR + 1, 3, NR - 1),
        (MR - 1, 5, 2 * NR + 3),
        (2 * MR, 7, NR),
        (5, 33, 17),
        (7, 96, 31),
    ]
}

fn random_shape(rng: &mut Pcg32) -> (usize, usize, usize) {
    let m = match rng.below(4) {
        0 => 1, // the serving shape stays hot
        _ => 1 + rng.below(32) as usize,
    };
    let k = match rng.below(4) {
        0 => 2 * rng.below(48) as usize + 1, // odd: pair-ragged
        _ => 1 + rng.below(96) as usize,
    };
    let n = match rng.below(4) {
        0 => 1 + NR * (1 + rng.below(3) as usize), // panel-aligned
        _ => 1 + rng.below(80) as usize,
    };
    (m, k, n)
}

fn check_gemm<A: QAct>(a: &[A], b: &[i8], (m, k, n): (usize, usize, usize), what: &str) {
    let want = gemm_with(KernelChoice::Scalar, a, b, m, k, n);
    for choice in TIERS {
        let got = gemm_with(choice, a, b, m, k, n);
        assert_eq!(got, want, "{what} {choice:?} vs scalar at ({m},{k},{n})");
    }
}

fn check_gemm_i4<A: QAct>(a: &[A], b4: &[i8], (m, k, n): (usize, usize, usize), what: &str) {
    let want = gemm_i4_with(KernelChoice::Scalar, a, b4, m, k, n);
    for choice in TIERS {
        let got = gemm_i4_with(choice, a, b4, m, k, n);
        assert_eq!(got, want, "{what} i4 {choice:?} vs scalar at ({m},{k},{n})");
    }
}

#[test]
fn gemm_tiers_bit_identical_i8_activations() {
    let mut rng = Pcg32::seeded(101);
    let shapes: Vec<_> =
        edge_shapes().into_iter().chain((0..200).map(|_| random_shape(&mut rng))).collect();
    for &(m, k, n) in &shapes {
        let a = draw_a8(&mut rng, m * k);
        let b = draw_w8(&mut rng, k * n);
        check_gemm(&a, &b, (m, k, n), "i8");
    }
}

#[test]
fn gemm_tiers_bit_identical_u8_activations() {
    let mut rng = Pcg32::seeded(103);
    let shapes: Vec<_> =
        edge_shapes().into_iter().chain((0..200).map(|_| random_shape(&mut rng))).collect();
    for &(m, k, n) in &shapes {
        let a = draw_u8(&mut rng, m * k);
        let b = draw_w8(&mut rng, k * n);
        check_gemm(&a, &b, (m, k, n), "u8");
    }
}

#[test]
fn gemm_int4_direct_bit_identical_both_activation_types() {
    let mut rng = Pcg32::seeded(107);
    let shapes: Vec<_> =
        edge_shapes().into_iter().chain((0..150).map(|_| random_shape(&mut rng))).collect();
    for &(m, k, n) in &shapes {
        let b4 = draw_w4(&mut rng, k * n);
        let a = draw_a8(&mut rng, m * k);
        check_gemm_i4(&a, &b4, (m, k, n), "i8-acts");
        let au = draw_u8(&mut rng, m * k);
        check_gemm_i4(&au, &b4, (m, k, n), "u8-acts");
    }
}

/// One shape above the `1 << 21` work threshold, so the row-panel
/// parallel driver path (and the reference's row-parallel path) is
/// exercised, not just the serial loops.
#[test]
fn gemm_tiers_bit_identical_on_the_parallel_path() {
    let mut rng = Pcg32::seeded(109);
    let (m, k, n) = (160, 96, 144); // 2.2M > 2^21
    let a = draw_a8(&mut rng, m * k);
    let b = draw_w8(&mut rng, k * n);
    check_gemm(&a, &b, (m, k, n), "parallel i8");
    let b4 = draw_w4(&mut rng, k * n);
    let au = draw_u8(&mut rng, m * k);
    check_gemm_i4(&au, &b4, (m, k, n), "parallel u8");
}

#[test]
fn conv_tiers_bit_identical_all_strides() {
    let mut rng = Pcg32::seeded(113);
    for case in 0..60 {
        let n = 1 + rng.below(3) as usize;
        let h = 1 + rng.below(8) as usize;
        let w = 1 + rng.below(8) as usize;
        let ci = 1 + rng.below(5) as usize;
        let kh = 1 + rng.below(4) as usize;
        let kw = 1 + rng.below(4) as usize;
        let co = 1 + rng.below(NR as u32 + 4) as usize;
        let stride = 1 + rng.below(3) as usize;
        let d = conv_shape(&[n, h, w, ci], &[kh, kw, ci, co], stride);
        let kk = kh * kw * ci;
        let w8 = draw_w8(&mut rng, kk * co);
        let w4 = draw_w4(&mut rng, kk * co);
        let what = format!("conv#{case} n{n} {h}x{w}x{ci} k{kh}x{kw} co{co} s{stride}");

        let xq = draw_a8(&mut rng, n * h * w * ci);
        let want = conv_int_with(KernelChoice::Scalar, &xq, &w8, &d);
        let want4 = conv_int_i4_with(KernelChoice::Scalar, &xq, &w4, &d);
        for choice in TIERS {
            assert_eq!(conv_int_with(choice, &xq, &w8, &d), want, "{what} {choice:?}");
            assert_eq!(conv_int_i4_with(choice, &xq, &w4, &d), want4, "{what} i4 {choice:?}");
        }

        let xu = draw_u8(&mut rng, n * h * w * ci);
        let want_u = conv_int_with(KernelChoice::Scalar, &xu, &w8, &d);
        for choice in TIERS {
            assert_eq!(conv_int_with(choice, &xu, &w8, &d), want_u, "{what} u8 {choice:?}");
        }
    }
}

/// The overflow blind spot the blocked rewrite closed: the zoo's widest
/// reductions sit far inside the i32 accumulator envelope, and the bound
/// itself is tight (`k · MAX_ABS · 128 ≤ i32::MAX`).
#[test]
fn accumulator_envelope_covers_every_zoo_reduction() {
    // (k, activation bound) per zoo layer family: mlp3 dense (k ≤ 64),
    // cnn6 convs (k = 27..576, u8 acts), ncf dense (k ≤ 96)
    for (k, a_max) in [(64, 128), (27, 255), (576, 255), (96, 128)] {
        assert!(acc_fits_i32(k, a_max), "k={k} a_max={a_max}");
    }
    assert!(acc_fits_i32(65807, 255));
    assert!(!acc_fits_i32(65808, 255));
}
