//! Mixed-precision integration: the DP bit allocator's contract pinned
//! on hand-checked instances, and the acceptance path end to end — a
//! mixed pack flows calibrate → allocate → pack → save → load →
//! [`InferSession`] → pool-server `infer` with bit-exact parity against
//! the fake-quant reference, under its plan-embedding registry key.

use lapq::config::{BitSpec, ExperimentConfig, Method, ServeCfg};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::service::request;
use lapq::data::vision::SynthVision;
use lapq::lapq::mixed::allocate;
use lapq::runtime::int::model::Payload;
use lapq::runtime::int::{weight_storage_bytes, ExecMode, InferSession, PackOpts, QuantizedModel};
use lapq::runtime::EngineHandle;
use lapq::serve::PoolServer;
use lapq::util::json::Json;

// ---------------------------------------------------------------- allocator

/// Realistic byte costs for three 64-element layers at bits [2, 4, 8].
fn costs3() -> Vec<Vec<usize>> {
    let per = |n: usize| vec![2, 4, 8].into_iter().map(|b| weight_storage_bytes(n, b)).collect();
    vec![per(64), per(64), per(64)]
}

#[test]
fn allocator_is_optimal_on_a_hand_checked_instance() {
    // sens[l][j] = loss increase at candidate j (bits ascending 2/4/8).
    // Budget 96 B = uniform W4.  Exhaustive check over the 27 plans puts
    // the optimum at [8, 2, 2]: 0.1 + 1.0 + 0.1 = 1.2 at exactly 96 B —
    // the sensitive layer 0 buys its 8 bits from the insensitive tail.
    let sens = vec![
        vec![10.0, 2.0, 0.1],
        vec![1.0, 0.3, 0.05],
        vec![0.1, 0.05, 0.0],
    ];
    let (pick, spent) = allocate(&costs3(), &sens, 96).unwrap();
    assert_eq!(pick, vec![2, 0, 0], "layer 0 gets 8 bits, the rest 2");
    assert_eq!(spent, 96);
}

#[test]
fn allocator_respects_the_budget_exactly() {
    let sens = vec![
        vec![10.0, 2.0, 0.1],
        vec![1.0, 0.3, 0.05],
        vec![0.1, 0.05, 0.0],
    ];
    // One byte under uniform W4: [8, 2, 2] (96 B) no longer fits, and the
    // best ≤95 B plan is [4, 4, 2] at 80 B (2.0 + 0.3 + 0.1 = 2.4).
    let (pick, spent) = allocate(&costs3(), &sens, 95).unwrap();
    assert!(spent <= 95, "spent {spent}");
    assert_eq!(pick, vec![1, 1, 0]);
    assert_eq!(spent, 80);
}

#[test]
fn ample_budget_degrades_to_uniform_max_bits() {
    // With room for everything, every layer takes the widest candidate —
    // a flat-sensitivity model must not be punished by the allocator.
    let sens = vec![vec![1.0, 0.5, 0.1]; 3];
    let (pick, spent) = allocate(&costs3(), &sens, 10_000).unwrap();
    assert_eq!(pick, vec![2, 2, 2]);
    assert_eq!(spent, 3 * weight_storage_bytes(64, 8));
}

// --------------------------------------------------------------- end to end

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

fn mixed_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp3".into();
    cfg.train_steps = 60;
    cfg.lr = 0.1;
    cfg.calib_size = 512;
    cfg.val_size = 1024;
    cfg.bits = BitSpec::new(4, 4);
    cfg.method = Method::Lapq;
    cfg.lapq.joint.max_evals = 120;
    cfg.lapq.joint.iters = 1;
    // all three layers in play, or the plan has a single degree of freedom
    cfg.lapq.exclude_first_last = false;
    cfg.mixed.enabled = true;
    cfg.mixed.budget_frac = 1.0;
    cfg.mixed.sharpness_k = 2;
    cfg
}

/// The issue's acceptance path: pack with allocation on, check the
/// plan-embedding key and the size budget, round-trip the artifact
/// through disk, serve it bit-exactly from an [`InferSession`] and from
/// the concurrent pool server, and see the plan echoed by
/// `{"cmd":"models"}`.
#[test]
fn mixed_pack_roundtrips_to_pool_serving_bit_exact() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let mut runner = Runner::new(eng.clone());
    let cfg = mixed_cfg();
    let (sum, qm) = runner.pack(&cfg, &PackOpts::default()).unwrap();

    // the registry key embeds the plan, so it can't collide with the
    // uniform pack of the same config
    assert!(sum.key.starts_with("mlp3:w["), "key {}", sum.key);
    assert_ne!(sum.key, Runner::pack_key(&cfg));
    assert_eq!(sum.wbits, qm.wbits());
    assert_eq!(sum.wbits.len(), 3);
    assert!(sum.wbits.iter().all(|b| [2, 4, 8].contains(b)), "{:?}", sum.wbits);

    // allocation honoured the uniform-W4 byte budget
    let (mixed_bytes, uniform_bytes) = qm
        .params
        .iter()
        .filter_map(|p| match &p.payload {
            Payload::Int { bits, q, .. } => {
                Some((weight_storage_bytes(q.len(), *bits), weight_storage_bytes(q.len(), 4)))
            }
            Payload::F32(_) => None,
        })
        .fold((0, 0), |(m, u), (a, b)| (m + a, u + b));
    assert!(mixed_bytes <= uniform_bytes, "{mixed_bytes} vs {uniform_bytes}");

    // disk round-trip preserves the heterogeneous payloads
    let dir = std::env::temp_dir().join(format!("lapq_mixed_e2e_{}", std::process::id()));
    qm.save(&dir).unwrap();
    let loaded = std::sync::Arc::new(QuantizedModel::load(&dir).unwrap());
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(*loaded, *qm);

    // integer engine vs fake-quant reference: bit-for-bit on mlp3
    let spec = runner.eng.manifest().model("mlp3").unwrap().clone();
    let sess = InferSession::new(&spec, &loaded).unwrap();
    let data = SynthVision::new(42);
    let (x, _) = data.batch_features(0, 4, 64);
    let int_res = sess.infer(&[x.clone()], ExecMode::Int).unwrap();
    let sim_res = sess.infer(&[x.clone()], ExecMode::Simulated).unwrap();
    assert_eq!(int_res.int_layers, 3);
    assert_bits_equal(&int_res.logits.data, &sim_res.logits.data, "mixed logits");

    // park the reloaded artifact in a pool server's registry and serve it
    let scfg = ServeCfg {
        workers: 2,
        batch_window_ms: 0.0,
        max_batch: 4,
        queue_bound: 16,
        registry_cap: 4,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng, scfg).unwrap();
    server.registry().put(sum.key.clone(), loaded);
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(2).unwrap());

    // {"cmd":"models"} echoes the resident pack with its bit plan
    let models = request(&addr, &Json::obj(vec![("cmd", Json::Str("models".into()))])).unwrap();
    let packs = models.req("packs").as_arr().expect("packs echoed");
    assert_eq!(packs.len(), 1);
    assert_eq!(packs[0].req("key").as_str(), Some(sum.key.as_str()));
    let echoed: Vec<u32> = packs[0]
        .req("wbits")
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|j| j.as_f64().map(|v| v as u32))
        .collect();
    assert_eq!(echoed, sum.wbits);

    // infer over the wire on the mixed key: identical bits to the local
    // session (f64 text is shortest-roundtrip, so f32 survives exactly)
    let row: Vec<f32> = x.f()[..64].to_vec();
    let infer = request(
        &addr,
        &Json::obj(vec![
            ("cmd", Json::Str("infer".into())),
            ("key", Json::Str(sum.key.clone())),
            ("x", Json::Arr(vec![Json::arr_f32(&row)])),
        ]),
    )
    .unwrap();
    assert_eq!(infer.req("ok").as_bool(), Some(true), "{infer:?}");
    let got: Vec<f32> = infer.req("result").req("logits").as_arr().unwrap()[0]
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|j| j.as_f64().map(|v| v as f32))
        .collect();
    assert_bits_equal(&got, &int_res.logits.data[..got.len()], "served mixed logits");
    pool.join().unwrap();
}
