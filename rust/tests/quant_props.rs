//! Property-based tests (in-repo `prop` framework) over the quantization
//! and optimization substrates.

use lapq::prop::{forall, Shrink};
use lapq::quant::lp::lp_error_sum;
use lapq::quant::minmax::minmax_delta;
use lapq::quant::mmse::{lp_optimal_delta, LpSearch};
use lapq::quant::quantizer::{fake_quant, fake_quant_one};
use lapq::quant::GridKind;
use lapq::util::json::Json;
use lapq::util::rng::Pcg32;

#[derive(Clone, Debug)]
struct Case {
    xs: Vec<f32>,
    delta: f32,
    bits: u32,
}

impl Shrink for Case {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for xs in self.xs.shrink() {
            if !xs.is_empty() {
                out.push(Case { xs, ..self.clone() })
            }
        }
        out
    }
}

fn case_gen(rng: &mut Pcg32) -> Case {
    let n = 1 + rng.below(512) as usize;
    Case { xs: rng.normal_vec(n), delta: rng.range(1e-3, 1.0), bits: 2 + rng.below(7) }
}

#[test]
fn prop_idempotent() {
    forall(11, 300, case_gen, |c: &Case| {
        let qmax = GridKind::Signed.qmax(c.bits);
        let once = fake_quant(&c.xs, c.delta, qmax, GridKind::Signed);
        let twice = fake_quant(&once, c.delta, qmax, GridKind::Signed);
        once.iter().zip(&twice).all(|(a, b)| (a - b).abs() < 1e-6)
    });
}

#[test]
fn prop_output_bounded_by_clip() {
    forall(12, 300, case_gen, |c: &Case| {
        let qmax = GridKind::Signed.qmax(c.bits);
        let clip = c.delta * qmax;
        fake_quant(&c.xs, c.delta, qmax, GridKind::Signed)
            .iter()
            .all(|&v| v.abs() <= clip + 1e-5)
    });
}

#[test]
fn prop_error_bounded_inside_range() {
    forall(13, 300, case_gen, |c: &Case| {
        let qmax = GridKind::Signed.qmax(c.bits);
        let clip = c.delta * qmax;
        c.xs.iter().all(|&x| {
            let err = (fake_quant_one(x, c.delta, qmax, GridKind::Signed) - x).abs();
            if x.abs() <= clip {
                err <= c.delta / 2.0 + 1e-5
            } else {
                (err - (x.abs() - clip)).abs() <= c.delta / 2.0 + 1e-5
            }
        })
    });
}

#[test]
fn prop_unsigned_never_negative() {
    forall(14, 200, case_gen, |c: &Case| {
        let qmax = GridKind::Unsigned.qmax(c.bits);
        fake_quant(&c.xs, c.delta, qmax, GridKind::Unsigned).iter().all(|&v| v >= 0.0)
    });
}

#[test]
fn prop_lp_search_beats_minmax_and_random_probe() {
    forall(15, 60, case_gen, |c: &Case| {
        if c.xs.iter().all(|&x| x == 0.0) {
            return true;
        }
        let qmax = GridKind::Signed.qmax(c.bits);
        let (d, e) = lp_optimal_delta(&c.xs, qmax, 2.0, GridKind::Signed, LpSearch::default());
        if d == 0.0 {
            return true;
        }
        let d_mm = minmax_delta(&c.xs, qmax, GridKind::Signed);
        let e_mm = lp_error_sum(&c.xs, d_mm, qmax, 2.0, GridKind::Signed);
        let e_probe = lp_error_sum(&c.xs, d * 1.37, qmax, 2.0, GridKind::Signed);
        e <= e_mm * 1.0001 && e <= e_probe * 1.0001
    });
}

#[test]
fn prop_powell_reaches_quadratic_minimum() {
    use lapq::optim::powell::{powell, PowellCfg};
    forall(
        16,
        25,
        |rng: &mut Pcg32| {
            let n = 2 + rng.below(5) as usize;
            rng.normal_vec(n)
        },
        |target: &Vec<f32>| {
            let n = target.len();
            let r = powell(
                &vec![0.0; n],
                &vec![-5.0; n],
                &vec![5.0; n],
                &PowellCfg { max_iter: 8, ftol: 1e-10, ..Default::default() },
                |x| {
                    x.iter()
                        .zip(target)
                        .map(|(a, &b)| (a - b.clamp(-4.9, 4.9) as f64).powi(2))
                        .sum()
                },
            );
            r.fx < 1e-2
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg32, depth: u32) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.normal() * 100.0).round() as f64),
            3 => Json::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(100))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall(
        17,
        300,
        |rng: &mut Pcg32| vec![rng.uniform()],
        |v: &Vec<f32>| {
            let mut rng = Pcg32::seeded((v[0] * 1e9) as u64);
            let j = random_json(&mut rng, 0);
            j.dump().parse::<Json>() == Ok(j)
        },
    );
}

#[test]
fn prop_histogram_mass_conserved() {
    use lapq::quant::histogram::AbsHistogram;
    forall(18, 200, case_gen, |c: &Case| {
        let h = AbsHistogram::build(&c.xs, 64);
        h.counts.iter().sum::<u64>() == c.xs.len() as u64
    });
}
