//! Property-based tests (in-repo `prop` framework) over the quantization
//! and optimization substrates.

use lapq::prop::{forall, Shrink};
use lapq::quant::lp::lp_error_sum;
use lapq::runtime::int::kernels::{rshift_rhe, FixedMult};
use lapq::quant::minmax::minmax_delta;
use lapq::quant::mmse::{lp_optimal_delta, LpSearch};
use lapq::quant::quantizer::{fake_quant, fake_quant_one};
use lapq::quant::GridKind;
use lapq::util::json::Json;
use lapq::util::rng::Pcg32;

#[derive(Clone, Debug)]
struct Case {
    xs: Vec<f32>,
    delta: f32,
    bits: u32,
}

impl Shrink for Case {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for xs in self.xs.shrink() {
            if !xs.is_empty() {
                out.push(Case { xs, ..self.clone() })
            }
        }
        out
    }
}

fn case_gen(rng: &mut Pcg32) -> Case {
    let n = 1 + rng.below(512) as usize;
    Case { xs: rng.normal_vec(n), delta: rng.range(1e-3, 1.0), bits: 2 + rng.below(7) }
}

#[test]
fn prop_idempotent() {
    forall(11, 300, case_gen, |c: &Case| {
        let qmax = GridKind::Signed.qmax(c.bits);
        let once = fake_quant(&c.xs, c.delta, qmax, GridKind::Signed);
        let twice = fake_quant(&once, c.delta, qmax, GridKind::Signed);
        once.iter().zip(&twice).all(|(a, b)| (a - b).abs() < 1e-6)
    });
}

#[test]
fn prop_output_bounded_by_clip() {
    forall(12, 300, case_gen, |c: &Case| {
        let qmax = GridKind::Signed.qmax(c.bits);
        let clip = c.delta * qmax;
        fake_quant(&c.xs, c.delta, qmax, GridKind::Signed)
            .iter()
            .all(|&v| v.abs() <= clip + 1e-5)
    });
}

#[test]
fn prop_error_bounded_inside_range() {
    forall(13, 300, case_gen, |c: &Case| {
        let qmax = GridKind::Signed.qmax(c.bits);
        let clip = c.delta * qmax;
        c.xs.iter().all(|&x| {
            let err = (fake_quant_one(x, c.delta, qmax, GridKind::Signed) - x).abs();
            if x.abs() <= clip {
                err <= c.delta / 2.0 + 1e-5
            } else {
                (err - (x.abs() - clip)).abs() <= c.delta / 2.0 + 1e-5
            }
        })
    });
}

#[test]
fn prop_unsigned_never_negative() {
    forall(14, 200, case_gen, |c: &Case| {
        let qmax = GridKind::Unsigned.qmax(c.bits);
        fake_quant(&c.xs, c.delta, qmax, GridKind::Unsigned).iter().all(|&v| v >= 0.0)
    });
}

#[test]
fn prop_lp_search_beats_minmax_and_random_probe() {
    forall(15, 60, case_gen, |c: &Case| {
        if c.xs.iter().all(|&x| x == 0.0) {
            return true;
        }
        let qmax = GridKind::Signed.qmax(c.bits);
        let (d, e) = lp_optimal_delta(&c.xs, qmax, 2.0, GridKind::Signed, LpSearch::default());
        if d == 0.0 {
            return true;
        }
        let d_mm = minmax_delta(&c.xs, qmax, GridKind::Signed);
        let e_mm = lp_error_sum(&c.xs, d_mm, qmax, 2.0, GridKind::Signed);
        let e_probe = lp_error_sum(&c.xs, d * 1.37, qmax, 2.0, GridKind::Signed);
        e <= e_mm * 1.0001 && e <= e_probe * 1.0001
    });
}

#[test]
fn prop_powell_reaches_quadratic_minimum() {
    use lapq::optim::powell::{powell, PowellCfg};
    forall(
        16,
        25,
        |rng: &mut Pcg32| {
            let n = 2 + rng.below(5) as usize;
            rng.normal_vec(n)
        },
        |target: &Vec<f32>| {
            let n = target.len();
            let r = powell(
                &vec![0.0; n],
                &vec![-5.0; n],
                &vec![5.0; n],
                &PowellCfg { max_iter: 8, ftol: 1e-10, ..Default::default() },
                |x| {
                    x.iter()
                        .zip(target)
                        .map(|(a, &b)| (a - b.clamp(-4.9, 4.9) as f64).powi(2))
                        .sum()
                },
            );
            r.fx < 1e-2
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg32, depth: u32) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.normal() * 100.0).round() as f64),
            3 => Json::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(100))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall(
        17,
        300,
        |rng: &mut Pcg32| vec![rng.uniform()],
        |v: &Vec<f32>| {
            let mut rng = Pcg32::seeded((v[0] * 1e9) as u64);
            let j = random_json(&mut rng, 0);
            j.dump().parse::<Json>() == Ok(j)
        },
    );
}

// ---------------------------------------------------------------------
// Requantization arithmetic: `rshift_rhe` and `FixedMult::apply`
// ---------------------------------------------------------------------

/// A shifted-rounding case: `x / 2^b`, `|x| < 2^62` (the documented
/// domain of `rshift_rhe`), with exact `.5` ties injected deliberately.
#[derive(Clone, Debug)]
struct ShiftCase {
    x: i64,
    b: u32,
}

impl Shrink for ShiftCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.x != 0 {
            out.push(ShiftCase { x: self.x / 2, b: self.b });
        }
        if self.b > 0 {
            out.push(ShiftCase { x: self.x, b: self.b / 2 });
        }
        out
    }
}

fn shift_gen(rng: &mut Pcg32) -> ShiftCase {
    let b = rng.below(64);
    // up to 62 random bits, magnitude spread across every width
    let raw = ((rng.below(1 << 31) as i64) << 31) | rng.below(1 << 31) as i64;
    let width = rng.below(63);
    let mut x = raw & ((1i64 << width) - 1);
    if b > 0 && b < 63 && rng.below(4) == 0 {
        // land exactly on a round-half tie
        x = (x >> b << b) | (1i64 << (b - 1));
    }
    if rng.below(2) == 1 {
        x = -x;
    }
    ShiftCase { x, b }
}

/// Independent round-half-even reference for `x / 2^b`, in i128 euclid
/// arithmetic (f64 cannot represent the 62-bit operands exactly).
fn rhe_shift_ref(x: i64, b: u32) -> i64 {
    let d = 1i128 << b;
    let q = (x as i128).div_euclid(d);
    let r = (x as i128).rem_euclid(d);
    let half = d / 2;
    (q + if b > 0 && (r > half || (r == half && q & 1 != 0)) { 1 } else { 0 }) as i64
}

/// f64 round-half-to-even (MSRV predates `round_ties_even`).
fn rhe64(v: f64) -> f64 {
    let r = v.round();
    if (v - v.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
        r - v.signum()
    } else {
        r
    }
}

#[test]
fn prop_rshift_rhe_matches_euclid_reference() {
    forall(21, 600, shift_gen, |c: &ShiftCase| rshift_rhe(c.x, c.b) == rhe_shift_ref(c.x, c.b));
}

#[test]
fn prop_rshift_rhe_monotone_and_half_ulp_close() {
    forall(22, 500, shift_gen, |c: &ShiftCase| {
        let y = rshift_rhe(c.x, c.b);
        if rshift_rhe(c.x.saturating_add(1), c.b) < y {
            return false; // rounding must be monotone in x
        }
        if c.b == 0 || c.b >= 63 {
            return y == rhe_shift_ref(c.x, c.b);
        }
        // the rounded quotient is within half an output ulp of x/2^b
        ((y as i128) << c.b).abs_diff(c.x as i128) <= 1u128 << (c.b - 1)
    });
}

#[test]
fn prop_rshift_rhe_agrees_with_f64_where_f64_is_exact() {
    forall(23, 500, shift_gen, |c: &ShiftCase| {
        // restrict to the regime where both x and x/2^b are exact in f64
        let x = c.x % (1i64 << 52);
        let b = c.b.min(40);
        rshift_rhe(x, b) == rhe64(x as f64 / f64::powi(2.0, b as i32)) as i64
    });
}

/// i32 accumulators sampled at the boundaries of the range, plus noise.
fn acc_gen(rng: &mut Pcg32) -> i32 {
    match rng.below(3) {
        0 => [i32::MIN, i32::MIN + 1, -1, 0, 1, i32::MAX - 1, i32::MAX][rng.below(7) as usize],
        _ => rng.below(u32::MAX) as i32,
    }
}

#[derive(Clone, Debug)]
struct MultCase {
    exp: i32,
    frac: f32,
    acc: i32,
    acc2: i32,
}

impl Shrink for MultCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.acc != 0 {
            out.push(MultCase { acc: self.acc / 2, ..self.clone() });
        }
        if self.exp != 0 {
            out.push(MultCase { exp: self.exp / 2, ..self.clone() });
        }
        out
    }
}

fn mult_gen(rng: &mut Pcg32) -> MultCase {
    MultCase {
        exp: rng.below(26) as i32 - 20,
        frac: rng.range(0.5, 1.0),
        acc: acc_gen(rng),
        acc2: acc_gen(rng),
    }
}

#[test]
fn prop_fixed_mult_power_of_two_is_an_exact_shift() {
    forall(24, 400, mult_gen, |c: &MultCase| {
        let fm = FixedMult::from_f32(f32::powi(2.0, c.exp));
        let want = if c.exp >= 0 {
            (c.acc as i64) << c.exp
        } else {
            rhe_shift_ref(c.acc as i64, (-c.exp) as u32)
        };
        fm.apply(c.acc) == want
    });
}

#[test]
fn prop_fixed_mult_close_to_f64_product_and_monotone() {
    forall(25, 400, mult_gen, |c: &MultCase| {
        let m = c.frac * f32::powi(2.0, c.exp);
        let fm = FixedMult::from_f32(m);
        let (lo, hi) = (c.acc.min(c.acc2), c.acc.max(c.acc2));
        if fm.apply(lo) > fm.apply(hi) {
            return false; // positive multiplier: monotone in acc
        }
        let exact = c.acc as f64 * m as f64;
        (fm.apply(c.acc) as f64 - exact).abs() <= 0.5 + exact.abs() * 1e-6
    });
}

#[test]
fn prop_histogram_mass_conserved() {
    use lapq::quant::histogram::AbsHistogram;
    forall(18, 200, case_gen, |c: &Case| {
        let h = AbsHistogram::build(&c.xs, 64);
        h.counts.iter().sum::<u64>() == c.xs.len() as u64
    });
}
