//! The readiness-polled serving core (`serve.io = poll`) under hostile
//! and high-fan-in clients: slow-loris partial lines, a never-reading
//! client tripping the output-queue cap, oversized inputs, pipelined
//! request-id multiplexing, graceful drain in both io modes, and the
//! acceptance test — dozens of idle connections plus eight active
//! clients whose JSON / bin1 / streamed / multiplexed responses are
//! byte-identical to the blocking service's.
#![cfg(unix)]

use lapq::config::{BitSpec, ExperimentConfig, IoMode, Method, ServeCfg};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::service::Service;
use lapq::proto::wire::{Client, Incoming, WireReader};
use lapq::proto::{frame, InferRequest, ReqId, Request};
use lapq::runtime::EngineHandle;
use lapq::serve::PoolServer;
use lapq::tensor::HostTensor;
use lapq::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn poll_cfg() -> ServeCfg {
    ServeCfg {
        io: IoMode::Poll,
        workers: 2,
        batch_window_ms: 0.0,
        max_batch: 8,
        queue_bound: 64,
        registry_cap: 4,
        ..Default::default()
    }
}

/// A raw wire connection: bytes out, lines / frames in.  Unlike
/// [`Client`] it hands back the exact payload bytes, which is what the
/// byte-identity assertions need.
struct Raw {
    w: TcpStream,
    r: WireReader<TcpStream>,
}

impl Raw {
    fn connect(addr: &SocketAddr) -> Raw {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        s.set_write_timeout(Some(Duration::from_secs(120))).unwrap();
        let w = s.try_clone().unwrap();
        Raw { w, r: WireReader::new(s) }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.w.write_all(bytes).unwrap();
        self.w.flush().unwrap();
    }

    fn line(&mut self) -> String {
        match self.r.next() {
            Incoming::Line => self.r.line().to_string(),
            Incoming::Frame(k) => panic!("expected line, got frame kind {k}"),
            Incoming::Eof => panic!("expected line, got eof"),
            Incoming::TooLarge { .. } => panic!("expected line, got too-large"),
            Incoming::Corrupt(e) => panic!("expected line, got corrupt: {e}"),
        }
    }

    fn frame(&mut self) -> (u8, Vec<u8>) {
        match self.r.next() {
            Incoming::Frame(k) => (k, self.r.payload().to_vec()),
            Incoming::Line => panic!("expected frame, got line {}", self.r.line()),
            Incoming::Eof => panic!("expected frame, got eof"),
            Incoming::TooLarge { .. } => panic!("expected frame, got too-large"),
            Incoming::Corrupt(e) => panic!("expected frame, got corrupt: {e}"),
        }
    }
}

/// Zero the wall-clock `"seconds"` value in a JSON reply so the rest of
/// the response can be compared byte for byte across servers.
fn normalize_seconds(line: &str) -> String {
    match line.find("\"seconds\":") {
        None => line.to_string(),
        Some(i) => {
            let start = i + "\"seconds\":".len();
            let end = line[start..]
                .find([',', '}'])
                .map(|j| start + j)
                .expect("seconds value is delimited");
            format!("{}0{}", &line[..start], &line[end..])
        }
    }
}

/// Zero the f64 `seconds` field inside a bin1 `KIND_INFER_REP` payload
/// (it sits after the length-prefixed key, `rows` and `int_layers`).
fn normalize_rep_payload(mut payload: Vec<u8>) -> Vec<u8> {
    let keylen = u16::from_le_bytes([payload[0], payload[1]]) as usize;
    let off = 2 + keylen + 4 + 4;
    payload[off..off + 8].fill(0);
    payload
}

// ------------------------------------------------------------ adversarial

/// A slow-loris client drips one byte at a time; the reactor's feed
/// decoder must assemble the line across reads and answer normally.
/// Pipelined id-tagged requests split at an awkward boundary come back
/// in order, each echoing its id.  A `shutdown` on the same connection
/// gets the typed `stopping` reply, the output is flushed, and the
/// reactor closes the socket (graceful drain covers reactor-owned
/// connections).
#[test]
fn slow_loris_lines_are_assembled_and_answered() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let server = PoolServer::bind("127.0.0.1:0", eng, poll_cfg()).unwrap();
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(usize::MAX).unwrap());

    let mut c = Raw::connect(&addr);
    for b in b"{\"cmd\":\"ping\"}\n" {
        c.send(std::slice::from_ref(b));
        std::thread::sleep(Duration::from_millis(2));
    }
    let pong = c.line();
    assert_eq!(pong, "{\"ok\":true,\"pong\":true}");

    // two pipelined requests, the split landing mid-way through the
    // second line: both answered, in order, ids echoed
    let two = b"{\"cmd\":\"ping\",\"id\":1}\n{\"cmd\":\"ping\",\"id\":2}\n";
    let cut = two.len() - 7;
    c.send(&two[..cut]);
    std::thread::sleep(Duration::from_millis(20));
    c.send(&two[cut..]);
    assert_eq!(c.line(), "{\"id\":1,\"ok\":true,\"pong\":true}");
    assert_eq!(c.line(), "{\"id\":2,\"ok\":true,\"pong\":true}");

    // shutdown over the wire: stopping reply, flush, server-side close
    c.send(b"{\"cmd\":\"shutdown\"}\n");
    let stopping = c.line();
    assert!(stopping.contains("\"stopping\":true"), "{stopping}");
    assert!(matches!(c.r.next(), Incoming::Eof), "drained connection must close");
    pool.join().unwrap();
}

/// A client that writes forever but never reads: once the kernel socket
/// buffers fill, responses back up in the connection's output queue
/// until the `out_queue_kib` cap trips — then the reactor sheds the
/// connection (typed overload, best-effort flush, close) instead of
/// buffering without bound.  The server stays healthy for new clients.
#[test]
fn never_reading_client_is_capped_and_closed() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let cfg = ServeCfg { out_queue_kib: 1, ..poll_cfg() };
    let server = PoolServer::bind("127.0.0.1:0", eng, cfg).unwrap();
    let addr = server.addr;
    let handle = server.shutdown_handle();
    let pool = std::thread::spawn(move || server.serve(usize::MAX).unwrap());

    // Each unknown-cmd request echoes its ~1 KiB id, so every line sent
    // comes back about as big; a few thousand of them overwhelm any
    // kernel buffering long before the sender runs out.
    let big_id = "x".repeat(1024);
    let req = format!("{{\"cmd\":\"nope\",\"id\":\"{big_id}\"}}\n");
    let chunk = req.repeat(100).into_bytes();
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut w = s.try_clone().unwrap();
    let mut closed_while_writing = false;
    for _ in 0..400 {
        // ~40 MiB if the server never pushed back — it must close long
        // before that, surfacing here as a write error
        if w.write_all(&chunk).is_err() {
            closed_while_writing = true;
            break;
        }
    }
    // Drain whatever the server managed to flush (possibly including
    // the typed overload line — delivery isn't guaranteed once the
    // connection is torn down) and require the close itself.
    let mut r = BufReader::new(s);
    let mut saw_close = closed_while_writing;
    let mut line = String::new();
    loop {
        line.clear();
        match r.read_line(&mut line) {
            Ok(0) => {
                saw_close = true;
                break;
            }
            Ok(_) => continue,
            Err(_) => {
                saw_close = true;
                break;
            }
        }
    }
    assert!(saw_close, "server must close a connection that never reads");
    drop(w);

    // the reactor itself is unharmed: a fresh client gets served
    let mut fresh = Client::connect(&addr).unwrap();
    let pong = fresh.call(&Request::Ping).unwrap();
    assert_eq!(pong.req("pong").as_bool(), Some(true));
    drop(fresh);
    handle.shutdown();
    pool.join().unwrap();
}

/// Oversized inputs under the reactor: an endless line and a frame
/// header promising more than the frame cap both get the typed
/// `too_large` reply before the connection closes — same contract the
/// blocking path pins in `wire_bin.rs`.
#[test]
fn oversized_inputs_get_typed_replies_under_poll() {
    use lapq::proto::{MAX_FRAME_BYTES, MAX_LINE_BYTES};
    let eng = EngineHandle::start_default().expect("engine boots");
    let server = PoolServer::bind("127.0.0.1:0", eng, poll_cfg()).unwrap();
    let addr = server.addr;
    let handle = server.shutdown_handle();
    let pool = std::thread::spawn(move || server.serve(usize::MAX).unwrap());

    // endless line: typed reply as soon as the cap is crossed, then close
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut w = s.try_clone().unwrap();
    let mut r = BufReader::new(s);
    let chunk = vec![b'x'; 8 * 1024];
    let mut sent = 0usize;
    while sent <= MAX_LINE_BYTES + chunk.len() {
        if w.write_all(&chunk).is_err() {
            break;
        }
        sent += chunk.len();
    }
    let _ = w.flush();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let j: Json = line.parse().expect("typed too_large reply");
    assert_eq!(j.req("error").as_str(), Some("too_large"), "{j:?}");
    assert_eq!(j.req("limit_bytes").as_f64(), Some(MAX_LINE_BYTES as f64), "{j:?}");
    line.clear();
    assert_eq!(r.read_line(&mut line).unwrap(), 0, "oversized line closes the connection");
    drop(w);

    // oversized frame header: refused from the 8 header bytes alone
    let mut c = Raw::connect(&addr);
    let mut hdr = vec![frame::MARKER, frame::MAGIC2, frame::VERSION, frame::KIND_INFER_REQ];
    hdr.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
    c.send(&hdr);
    let j: Json = c.line().parse().expect("typed too_large reply");
    assert_eq!(j.req("error").as_str(), Some("too_large"), "{j:?}");
    assert_eq!(j.req("limit_bytes").as_f64(), Some(MAX_FRAME_BYTES as f64), "{j:?}");
    assert!(matches!(c.r.next(), Incoming::Eof), "oversized frame closes the connection");

    handle.shutdown();
    pool.join().unwrap();
}

/// Request-id multiplexing on one pipelined connection: three requests
/// with distinct ids (number, string, and an id on a failing request)
/// come back in submission order, each echoing its own id.
#[test]
fn pipelined_ids_are_echoed_in_order() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let server = PoolServer::bind("127.0.0.1:0", eng, poll_cfg()).unwrap();
    let addr = server.addr;
    let handle = server.shutdown_handle();
    let pool = std::thread::spawn(move || server.serve(usize::MAX).unwrap());

    let mut c = Raw::connect(&addr);
    c.send(
        b"{\"cmd\":\"ping\",\"id\":7}\n\
          {\"cmd\":\"bogus\",\"id\":\"a\"}\n\
          {\"cmd\":\"infer\",\"id\":3,\"key\":\"nope\",\"x\":[[0.5]]}\n",
    );
    assert_eq!(c.line(), "{\"id\":7,\"ok\":true,\"pong\":true}");
    assert_eq!(c.line(), "{\"cmd\":\"bogus\",\"error\":\"unknown_cmd\",\"id\":\"a\",\"ok\":false}");
    let third = c.line();
    assert!(third.contains("\"id\":3"), "{third}");
    assert!(third.contains("no packed model"), "{third}");
    drop(c);
    handle.shutdown();
    pool.join().unwrap();
}

// -------------------------------------------------------------- drain

/// `{"cmd":"shutdown"}` drains gracefully in both io modes: in-flight
/// requests finish, outputs flush, and the server thread joins.  The
/// reactor also closes its idle connections itself; the threads mode
/// needs the clients to hang up (each blocking worker owns its socket).
#[test]
fn graceful_drain_covers_both_io_modes() {
    for io in [IoMode::Threads, IoMode::Poll] {
        let eng = EngineHandle::start_default().expect("engine boots");
        let cfg = ServeCfg { io, ..poll_cfg() };
        let server = PoolServer::bind("127.0.0.1:0", eng, cfg).unwrap();
        let addr = server.addr;
        let pool = std::thread::spawn(move || server.serve(usize::MAX).unwrap());

        let mut idle = Raw::connect(&addr);
        idle.send(b"{\"cmd\":\"ping\"}\n");
        assert_eq!(idle.line(), "{\"ok\":true,\"pong\":true}", "{io:?}: idle warm-up");

        let mut c = Raw::connect(&addr);
        c.send(b"{\"cmd\":\"shutdown\"}\n");
        let stopping = c.line();
        assert!(stopping.contains("\"stopping\":true"), "{io:?}: {stopping}");

        if matches!(io, IoMode::Poll) {
            // the reactor finishes the flush and closes both sockets
            assert!(matches!(c.r.next(), Incoming::Eof), "poll closes the shutdown conn");
            assert!(matches!(idle.r.next(), Incoming::Eof), "poll closes idle conns on drain");
        } else {
            // blocking workers sit in read() until their clients leave
            drop(c);
            drop(idle);
        }
        pool.join().unwrap();
    }
}

// ---------------------------------------------------------- acceptance

/// The tentpole acceptance test: a poll server carrying 64 idle
/// connections and 8 concurrent active clients answers JSON, streamed
/// JSON, and streamed+multiplexed bin1 infers **byte-identically** to
/// the blocking service over the same packed model (wall-clock
/// `seconds` zeroed on both sides).  The idle connections stay live
/// through all of it.
#[test]
fn idle_fanin_active_clients_match_blocking_byte_for_byte() {
    const IDLE: usize = 64;
    const ACTIVE: usize = 8;
    const ROWS: usize = 40; // past STREAM_CHUNK_ROWS, so streams chunk

    let eng = EngineHandle::start_default().expect("engine boots");
    let cfg = ServeCfg { max_conns: 256, ..poll_cfg() };
    let server = PoolServer::bind("127.0.0.1:0", eng.clone(), cfg).unwrap();
    let pack = ExperimentConfig {
        model: "mlp3".into(),
        train_steps: 40,
        lr: 0.1,
        val_size: 512,
        bits: BitSpec::new(8, 8),
        method: Method::Mmse,
        ..Default::default()
    };
    let key = server.preload(std::slice::from_ref(&pack)).unwrap().remove(0);
    let registry = server.registry();
    let addr = server.addr;
    let handle = server.shutdown_handle();
    let pool = std::thread::spawn(move || server.serve(usize::MAX).unwrap());

    // the blocking reference serves the same registry; every active
    // client opens 3 connections against it
    let seq = Service::bind("127.0.0.1:0").unwrap();
    let seq_addr = seq.addr;
    let seq_thread = std::thread::spawn(move || {
        let mut runner = Runner::with_registry(eng, registry);
        seq.serve(&mut runner, ACTIVE * 3).unwrap();
    });

    let mut idles: Vec<Raw> = (0..IDLE).map(|_| Raw::connect(&addr)).collect();

    let workers: Vec<_> = (0..ACTIVE)
        .map(|t| {
            let key = key.clone();
            std::thread::spawn(move || {
                let data: Vec<f32> =
                    (0..ROWS * 64).map(|j| ((j * 31 + t * 7) % 17) as f32 * 0.125 - 1.0).collect();
                let ir = InferRequest {
                    key: key.clone(),
                    inputs: vec![HostTensor::f32(vec![ROWS, 64], data)],
                };
                let mut line = String::new();
                Request::Infer(ir.clone()).write_json(&mut line);

                // (a) plain JSON infer, id-tagged
                let with_id = format!("{{\"id\":{t},{}", &line[1..]);
                let reply = |addr: &SocketAddr| {
                    let mut c = Raw::connect(addr);
                    c.send(with_id.as_bytes());
                    c.send(b"\n");
                    c.line()
                };
                let got = reply(&addr);
                let want = reply(&seq_addr);
                assert!(got.contains(&format!("\"id\":{t}")), "{got}");
                assert_eq!(normalize_seconds(&got), normalize_seconds(&want), "JSON infer");

                // (b) streamed JSON: hello json+stream, then chunk lines
                // and the terminal line, all byte-compared
                let stream_json = |addr: &SocketAddr| -> (String, Vec<String>) {
                    let mut c = Raw::connect(addr);
                    c.send(b"{\"cmd\":\"hello\",\"wire\":\"json\",\"stream\":true}\n");
                    let hello = c.line();
                    c.send(with_id.as_bytes());
                    c.send(b"\n");
                    let mut lines = Vec::new();
                    loop {
                        let l = c.line();
                        let done = l.parse::<Json>().unwrap().get("ok").is_some();
                        lines.push(l);
                        if done {
                            break;
                        }
                    }
                    (hello, lines)
                };
                let (ph, plines) = stream_json(&addr);
                let (sh, slines) = stream_json(&seq_addr);
                assert_eq!(ph, sh, "stream hello");
                assert_eq!(plines.len(), 3, "two chunks + terminal for {ROWS} rows: {plines:?}");
                let norm = |v: &[String]| -> Vec<String> {
                    v.iter().map(|l| normalize_seconds(l)).collect()
                };
                assert_eq!(norm(&plines), norm(&slines), "streamed JSON lines");

                // (c) streamed bin1 with a string id: chunk frames
                // verbatim, terminal reply with seconds zeroed
                let id = ReqId::Str(format!("t{t}"));
                let mut fbuf = Vec::new();
                frame::encode_infer_request_id(&ir, Some(&id), &mut fbuf);
                let stream_bin = |addr: &SocketAddr| -> (String, Vec<(u8, Vec<u8>)>) {
                    let mut c = Raw::connect(addr);
                    c.send(b"{\"cmd\":\"hello\",\"wire\":\"bin1\",\"stream\":true}\n");
                    let hello = c.line();
                    c.send(&fbuf);
                    let mut frames = Vec::new();
                    loop {
                        let (kind, payload) = c.frame();
                        let done = kind == frame::KIND_INFER_REP;
                        frames.push((kind, payload));
                        if done {
                            break;
                        }
                    }
                    (hello, frames)
                };
                let (ph, pframes) = stream_bin(&addr);
                let (sh, sframes) = stream_bin(&seq_addr);
                assert_eq!(ph, sh, "bin1 stream hello");
                assert_eq!(pframes.len(), 3, "two chunk frames + terminal: {}", pframes.len());
                assert_eq!(pframes.len(), sframes.len());
                for (i, ((pk, pp), (sk, sp))) in pframes.into_iter().zip(sframes).enumerate() {
                    assert_eq!(pk, sk, "frame {i} kind");
                    if pk == frame::KIND_INFER_REP {
                        assert_eq!(
                            normalize_rep_payload(pp),
                            normalize_rep_payload(sp),
                            "terminal reply payload"
                        );
                    } else {
                        assert_eq!(pk, frame::KIND_INFER_CHUNK);
                        assert_eq!(pp, sp, "chunk frame {i} payload");
                    }
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    seq_thread.join().unwrap();

    // the fan-in never displaced the idle connections: each still
    // answers on the same socket it opened before the storm
    for (i, idle) in idles.iter_mut().enumerate() {
        if i % 16 == 0 {
            idle.send(b"{\"cmd\":\"ping\"}\n");
            assert_eq!(idle.line(), "{\"ok\":true,\"pong\":true}", "idle conn {i}");
        }
    }
    handle.shutdown();
    pool.join().unwrap();
}
