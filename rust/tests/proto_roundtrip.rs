//! Protocol invariants, no sockets involved: every `Request`/`Response`
//! variant survives JSON -> typed -> JSON losslessly, and the borrowing
//! reader agrees with the owned parser on a corpus of valid / invalid /
//! edge-case documents (both front-ends share one `Reader`, so this
//! pins the contract rather than two implementations).

use lapq::config::ExperimentConfig;
use lapq::coordinator::jobs::{InferReply, PackSummary};
use lapq::proto::{InferRequest, Request, Response};
use lapq::runtime::cpu::ops::Arr;
use lapq::tensor::HostTensor;
use lapq::util::json::{Json, Reader, MAX_DEPTH};

fn req_line(req: &Request) -> String {
    let mut s = String::new();
    req.write_json(&mut s);
    s
}

fn resp_line(resp: &Response) -> String {
    let mut s = String::new();
    resp.write_json(&mut s);
    s
}

#[test]
fn request_roundtrip_every_variant() {
    let cfg = ExperimentConfig { model: "mlp3".into(), train_steps: 40, ..Default::default() };
    let reqs = vec![
        Request::Ping,
        Request::Models,
        Request::Metrics,
        Request::Shutdown,
        Request::Hello { wire: "bin1".into(), stream: false },
        Request::Hello { wire: "json".into(), stream: true },
        Request::Quantize { cfg: Box::new(cfg.clone()), stream: true },
        Request::Quantize { cfg: Box::new(cfg.clone()), stream: false },
        Request::Pack { cfg: Box::new(cfg), po2: false },
        // nested rows (feature models)
        Request::Infer(InferRequest {
            key: "mlp3-int8".into(),
            inputs: vec![HostTensor::f32(vec![2, 3], vec![0.1, -2.0, 3.5, 0.0, 1.0, -0.25])],
        }),
        // flat + shape (images)
        Request::Infer(InferRequest {
            key: "cnn6-int4".into(),
            inputs: vec![HostTensor::f32(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0])],
        }),
        // users + items (ncf)
        Request::Infer(InferRequest {
            key: "ncf-int8".into(),
            inputs: vec![
                HostTensor::i32(vec![3], vec![1, 2, 3]),
                HostTensor::i32(vec![3], vec![9, 8, 7]),
            ],
        }),
        Request::Unknown { cmd: "frobnicate".into() },
    ];
    for req in reqs {
        let line = req_line(&req);
        let back = Request::from_line(&line)
            .unwrap_or_else(|e| panic!("reparse of {line}: {e}"));
        assert_eq!(req_line(&back), line, "lossless round-trip");
        // the line itself is valid JSON for any line-oriented tooling
        line.parse::<Json>().expect("request lines are JSON");
    }
}

#[test]
fn response_roundtrip_every_variant() {
    let resps = vec![
        Response::Pong,
        Response::Stopping,
        Response::Hello { wire: "bin1".into(), stream: false },
        Response::Hello { wire: "json".into(), stream: true },
        Response::Models { models: vec!["mlp3".into(), "cnn6".into()], packs: vec![] },
        Response::Models {
            models: vec!["mlp3".into()],
            packs: vec![
                ("mlp3:w8a8:LAPQ".into(), vec![32, 8, 32]),
                ("cnn6:w[8.4.2]a4:LAPQ".into(), vec![8, 4, 2]),
            ],
        },
        Response::Metrics {
            metrics: Json::obj(vec![
                ("service_requests", Json::Num(17.0)),
                ("queue_depth", Json::Num(0.0)),
            ]),
        },
        Response::Quantize {
            result: Json::obj(vec![
                ("model", Json::Str("mlp3".into())),
                ("quant_metric", Json::Num(0.75)),
            ]),
        },
        Response::Pack {
            packed: PackSummary {
                key: "mlp3-int8-mmse".into(),
                model: "mlp3".into(),
                bits_label: "w8a8".into(),
                method: "mmse".into(),
                int_params: 1234,
                f32_bytes: 4936,
                packed_bytes: 1290,
                fp32_metric: 0.875,
                quant_metric: 0.8125,
                seconds: 0.5,
                wbits: vec![],
            },
        },
        // a mixed-precision pack carries its per-layer plan on the wire
        Response::Pack {
            packed: PackSummary {
                key: "cnn6:w[8.4.2]a4:LAPQ".into(),
                model: "cnn6".into(),
                bits_label: "w[8.4.2]a4".into(),
                method: "LAPQ".into(),
                int_params: 4321,
                f32_bytes: 9000,
                packed_bytes: 1500,
                fp32_metric: 0.9,
                quant_metric: 0.875,
                seconds: 0.75,
                wbits: vec![8, 4, 2],
            },
        },
        Response::Infer {
            reply: InferReply {
                key: "mlp3-int8-mmse".into(),
                logits: Arr::new(vec![2, 3], vec![0.5, -1.25, 2.0, 3.0, 3.0, -0.5]),
                rows: 2,
                int_layers: 3,
                seconds: 0.125,
            },
        },
        Response::Error { msg: "boom \"quoted\"".into() },
        Response::UnknownCmd { cmd: "frobnicate".into() },
        Response::TooLarge { limit_bytes: 8 << 20 },
        Response::Overloaded { retry_after_ms: 25 },
        Response::ModelNotPacked { key: "ghost:w8a8:MMSE".into() },
    ];
    for resp in resps {
        let line = resp_line(&resp);
        let back = Response::from_line(&line)
            .unwrap_or_else(|e| panic!("reparse of {line}: {e}"));
        assert_eq!(resp_line(&back), line, "lossless round-trip");
        line.parse::<Json>().expect("response lines are JSON");
    }
}

#[test]
fn typed_writers_match_the_value_tree_serializer() {
    // The hand-written response serializers must stay byte-compatible
    // with what a `Json::Obj` (BTreeMap, alphabetical keys) dump of the
    // same data produces — that is the pre-redesign wire format.
    let reply = InferReply {
        key: "k".into(),
        logits: Arr::new(vec![2, 2], vec![0.1, 0.7, -0.3, -0.9]),
        rows: 2,
        int_layers: 3,
        seconds: 0.0625,
    };
    let line = resp_line(&Response::Infer { reply });
    let tree: Json = line.parse().unwrap();
    assert_eq!(tree.dump(), line, "alphabetical keys, identical number formatting");

    let shed = resp_line(&Response::Overloaded { retry_after_ms: 40 });
    assert_eq!(shed, r#"{"error":"overloaded","ok":false,"retry_after_ms":40}"#);
    let unk = resp_line(&Response::UnknownCmd { cmd: "x".into() });
    assert_eq!(unk, r#"{"cmd":"x","error":"unknown_cmd","ok":false}"#);
    let big = resp_line(&Response::TooLarge { limit_bytes: 10 });
    assert_eq!(big, r#"{"error":"too_large","limit_bytes":10,"ok":false}"#);

    // the models response keeps alphabetical keys with packs present...
    let with_packs = resp_line(&Response::Models {
        models: vec!["mlp3".into()],
        packs: vec![("cnn6:w[8.4.2]a4:LAPQ".into(), vec![8, 4, 2])],
    });
    let tree: Json = with_packs.parse().unwrap();
    assert_eq!(tree.dump(), with_packs, "models+packs stays tree-serializer compatible");
    assert_eq!(
        with_packs,
        r#"{"models":["mlp3"],"ok":true,"packs":[{"key":"cnn6:w[8.4.2]a4:LAPQ","wbits":[8,4,2]}]}"#
    );
    // ...and omits the key entirely when no packs are resident, so the
    // pre-mixed wire format is emitted byte-for-byte.
    let no_packs = resp_line(&Response::Models { models: vec!["mlp3".into()], packs: vec![] });
    assert_eq!(no_packs, r#"{"models":["mlp3"],"ok":true}"#);
}

#[test]
fn infer_parse_errors_stay_typed() {
    let cases = [
        (r#"{"cmd":"infer","x":[[1,2]]}"#, "infer needs 'key'"),
        (r#"{"cmd":"infer","key":"k"}"#, "infer needs 'x'"),
        (r#"{"cmd":"infer","key":"k","x":[]}"#, "'x' is empty"),
        (r#"{"cmd":"infer","key":"k","x":[[1,2],[3]]}"#, "ragged"),
        (r#"{"cmd":"infer","key":"k","x":[1,2]}"#, "needs a 'shape'"),
        (r#"{"cmd":"infer","key":"k","x":[1,2],"shape":[3]}"#, "does not cover"),
        (r#"{"cmd":"infer","key":"k","x":[1,[2]]}"#, "mixed flat and nested"),
    ];
    for (line, want) in cases {
        let err = Request::from_line(line).expect_err(line).to_string();
        assert!(err.contains(want), "{line}: {err}");
    }
}

#[test]
fn request_ids_parse_and_echo() {
    use lapq::proto::ReqId;
    // numeric and string ids ride along with any command
    let (req, id) = Request::parse_line(r#"{"cmd":"ping","id":7}"#).unwrap();
    assert!(matches!(req, Request::Ping));
    assert_eq!(id, Some(ReqId::Num(7.0)));
    let (_, id) = Request::parse_line(r#"{"cmd":"ping","id":"req-1"}"#).unwrap();
    assert_eq!(id, Some(ReqId::Str("req-1".into())));
    // non-scalar ids are treated as absent, not an error
    let (_, id) = Request::parse_line(r#"{"cmd":"ping","id":[1]}"#).unwrap();
    assert_eq!(id, None);
    // id-less parse is unchanged
    let (_, id) = Request::parse_line(r#"{"cmd":"ping"}"#).unwrap();
    assert_eq!(id, None);

    // echo placement keeps alphabetical key order on every arm, so the
    // lines stay byte-compatible with a Json tree dump
    let cases: Vec<(Response, &str)> = vec![
        (Response::Pong, r#"{"id":7,"ok":true,"pong":true}"#),
        (
            Response::Overloaded { retry_after_ms: 40 },
            r#"{"error":"overloaded","id":7,"ok":false,"retry_after_ms":40}"#,
        ),
        (
            Response::TooLarge { limit_bytes: 10 },
            r#"{"error":"too_large","id":7,"limit_bytes":10,"ok":false}"#,
        ),
        (
            Response::UnknownCmd { cmd: "x".into() },
            r#"{"cmd":"x","error":"unknown_cmd","id":7,"ok":false}"#,
        ),
        (Response::Error { msg: "boom".into() }, r#"{"error":"boom","id":7,"ok":false}"#),
    ];
    for (resp, want) in cases {
        let mut s = String::new();
        resp.write_json_id(Some(&ReqId::Num(7.0)), &mut s);
        assert_eq!(s, want);
        let tree: Json = s.parse().unwrap();
        assert_eq!(tree.dump(), s, "id echo keeps tree-serializer byte compatibility");
        // and with no id, the historical bytes come out verbatim
        let mut bare = String::new();
        resp.write_json_id(None, &mut bare);
        assert_eq!(bare, resp_line(&resp));
    }
}

#[test]
fn stream_chunk_lines_are_tree_compatible() {
    use lapq::proto::{write_infer_chunk_json, write_infer_final_json, ReqId};
    let mut s = String::new();
    write_infer_chunk_json("k", 0, 2, &[0.5, -1.5, 2.0, 0.25], 2, None, &mut s);
    assert!(s.starts_with(r#"{"chunk":0,"chunks":2,"key":"k","logits":[["#), "{s}");
    assert!(!s.contains(r#""ok""#), "chunk frames carry no ok (the final does): {s}");
    let tree: Json = s.parse().unwrap();
    assert_eq!(tree.dump(), s, "chunk lines stay tree-serializer compatible");

    let mut s = String::new();
    write_infer_chunk_json("k", 1, 2, &[0.5], 1, Some(&ReqId::Str("a".into())), &mut s);
    assert!(s.starts_with(r#"{"chunk":1,"chunks":2,"id":"a","key":"k""#), "{s}");
    let tree: Json = s.parse().unwrap();
    assert_eq!(tree.dump(), s);

    let reply = InferReply {
        key: "k".into(),
        logits: Arr::new(vec![0, 2], vec![]),
        rows: 64,
        int_layers: 3,
        seconds: 0.5,
    };
    let mut f = String::new();
    write_infer_final_json(&reply, Some(&ReqId::Num(5.0)), &mut f);
    assert_eq!(
        f,
        r#"{"id":5,"ok":true,"result":{"int_layers":3,"key":"k","rows":64,"seconds":0.5,"streamed":true}}"#
    );
    let tree: Json = f.parse().unwrap();
    assert_eq!(tree.dump(), f);
}

#[test]
fn feed_decoder_matches_blocking_grammar() {
    use lapq::proto::frame;
    use lapq::proto::wire::{Feed, FeedDecoder};
    let mut d = FeedDecoder::new();
    // byte-at-a-time slow-loris still yields the exact line
    for b in b"{\"cmd\":\"ping\"}\r\n" {
        assert!(matches!(d.next(), Feed::More));
        d.push(&[*b]);
    }
    match d.next() {
        Feed::Line(l) => assert_eq!(l, r#"{"cmd":"ping"}"#, "\\r\\n stripped"),
        _ => panic!("expected a complete line"),
    }
    // pipelined lines come out in order from one push
    d.push(b"one\ntwo\n");
    assert!(matches!(d.next(), Feed::Line(l) if l == "one"));
    assert!(matches!(d.next(), Feed::Line(l) if l == "two"));
    assert!(matches!(d.next(), Feed::More));

    // a bin1 frame split at an arbitrary byte boundary reassembles
    let req = InferRequest {
        key: "k".into(),
        inputs: vec![HostTensor::f32(vec![1, 2], vec![0.5, -1.0])],
    };
    let mut buf = Vec::new();
    frame::encode_infer_request(&req, &mut buf);
    let split = buf.len() / 2;
    d.push(&buf[..split]);
    assert!(matches!(d.next(), Feed::More));
    d.push(&buf[split..]);
    match d.next() {
        Feed::Frame { kind, payload } => {
            assert_eq!(kind, frame::KIND_INFER_REQ);
            let (back, id) = frame::decode_infer_request_id(&payload).unwrap();
            assert_eq!(back.key, "k");
            assert_eq!(id, None);
        }
        _ => panic!("expected a complete frame"),
    }

    // corrupt CRC is fatal, exactly like the blocking reader
    let mut bad = buf.clone();
    let n = bad.len();
    bad[n - 1] ^= 0xFF;
    let mut d = FeedDecoder::new();
    d.push(&bad);
    assert!(matches!(d.next(), Feed::Corrupt(_)));

    // invalid UTF-8 in a line is corrupt, not a panic
    let mut d = FeedDecoder::new();
    d.push(&[0xC3, 0x28, b'\n']);
    assert!(matches!(d.next(), Feed::Corrupt(_)));

    // an unterminated line beyond the cap is too_large from the header
    // of the buffer alone (no newline required to detect the attack)
    let mut d = FeedDecoder::new();
    d.push(&vec![b'x'; lapq::proto::MAX_LINE_BYTES + 2]);
    assert!(matches!(d.next(), Feed::TooLarge { .. }));

    // an oversized frame is rejected from its 8-byte header, before any
    // body is buffered
    let mut d = FeedDecoder::new();
    let huge = (lapq::proto::MAX_FRAME_BYTES as u32) + 1;
    let mut hdr = vec![0xBF, b'Q', 1, 1];
    hdr.extend_from_slice(&huge.to_le_bytes());
    d.push(&hdr);
    assert!(matches!(d.next(), Feed::TooLarge { .. }));
}

/// Validate with the borrowing reader only (what the hot path does for
/// unknown keys): same grammar as the owned parser by construction,
/// pinned here over a corpus.
fn borrow_validate(text: &str) -> Result<(), String> {
    let mut r = Reader::new(text);
    r.skip_value(0)?;
    r.expect_end()
}

#[test]
fn parser_conformance_corpus() {
    let valid = [
        "0",
        "-0.5e-3",
        "1e15",
        "123456789012345",
        "true",
        "false",
        "null",
        "\"\"",
        r#""plain ascii""#,
        r#""esc \" \\ \/ \n \r \t \b \f""#,
        r#""café → done""#,
        "[]",
        "{}",
        "[1,2,[3,[4]],{\"a\":[]}]",
        r#"{"a":{"b":{"c":[1,2,3]}},"d":null}"#,
        "  [ 1 , 2 ]  ",
    ];
    let invalid = [
        "",
        "{",
        "[1,2",
        "[1,]",
        "{\"a\":}",
        "{\"a\" 1}",
        "{'a':1}",
        "\"unterminated",
        r#""bad \q escape""#,
        "tru",
        "+1",
        "[1] trailing",
        "1e999",
        "nan",
        "NaN",
        "Infinity",
    ];
    for t in valid {
        assert!(borrow_validate(t).is_ok(), "borrowing reader rejected valid: {t}");
        let j: Json = t.parse().unwrap_or_else(|e| panic!("owned parse of {t}: {e}"));
        // dump -> reparse is the identity on the tree
        let j2: Json = j.dump().parse().unwrap();
        assert_eq!(j, j2, "{t}");
    }
    for t in invalid {
        assert!(borrow_validate(t).is_err(), "borrowing reader accepted invalid: {t}");
        assert!(t.parse::<Json>().is_err(), "owned parser accepted invalid: {t}");
    }
    // wire input must not choose the recursion depth — both front-ends
    let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
    assert!(borrow_validate(&deep).is_err());
    assert!(deep.parse::<Json>().is_err());
}
