//! Integration: the runtime backend creates sessions, trains, evaluates
//! (FP32 and quantized) and collects activations — on the default CPU
//! backend (or the PJRT engine when built with `--features xla` over real
//! artifacts).

use lapq::data::vision::SynthVision;
use lapq::runtime::{EngineHandle, QuantParams};
use lapq::tensor::init::init_params;
use lapq::tensor::HostTensor;

fn engine() -> EngineHandle {
    EngineHandle::start_default().expect("engine boots")
}

#[test]
fn mlp3_full_roundtrip() {
    let eng = engine();
    let spec = eng.manifest().model("mlp3").unwrap().clone();
    let params = init_params(&spec.params, 1);
    let sess = eng.create_session("mlp3", params.clone()).unwrap();

    // batches from the synthetic vision set, projected to 64 features
    let data = SynthVision::new(7);
    let (x, y) = data.batch_features(0, spec.train_batch(), 64);
    let train_b = eng.register_batch(vec![x, y]).unwrap();
    let (xe, ye) = data.batch_features(10_000, spec.eval_batch(), 64);
    let eval_b = eng.register_batch(vec![xe, ye]).unwrap();

    // fp32 eval baseline
    let (loss0, correct0) = eng.eval(sess, None, eval_b).unwrap();
    assert!(loss0.is_finite() && loss0 > 0.0);
    assert!((0.0..=spec.eval_batch() as f32).contains(&correct0));

    // train several steps: loss must drop
    let mut losses = Vec::new();
    for _ in 0..30 {
        losses.push(eng.train_step(sess, train_b, 0.1).unwrap());
    }
    assert!(
        losses.last().unwrap() < &(losses[0] - 0.05),
        "train loss did not drop: {losses:?}"
    );

    // params actually changed and round-trip through get/set
    let trained = eng.get_params(sess).unwrap();
    assert_eq!(trained.len(), params.len());
    assert_ne!(trained[0].f(), params[0].f());
    eng.set_params(sess, trained.clone()).unwrap();

    // quantized eval with pass-through Δ == fp32 exactly
    let n = spec.n_quant_layers();
    let (lq, cq) = eng.eval(sess, Some(QuantParams::passthrough(n)), eval_b).unwrap();
    let (lf, cf) = eng.eval(sess, None, eval_b).unwrap();
    assert!((lq - lf).abs() < 1e-5, "{lq} vs {lf}");
    assert_eq!(cq, cf);

    // coarse quantization must change the loss
    let q = QuantParams {
        dw: vec![0.3; n],
        qmw: vec![1.0; n], // 2-bit signed
        da: vec![0.5; n],
        qma: vec![3.0; n],
    };
    let (lcoarse, _) = eng.eval(sess, Some(q), eval_b).unwrap();
    assert!((lcoarse - lf).abs() > 1e-3, "coarse {lcoarse} == fp32 {lf}");

    // acts takes only the inputs (no labels): one tensor per quant layer
    let (xa, _) = data.batch_features(10_000, spec.eval_batch(), 64);
    let acts_b = eng.register_batch(vec![xa]).unwrap();
    let acts = eng.acts(sess, acts_b).unwrap();
    assert_eq!(acts.len(), n);
    for a in &acts {
        assert_eq!(a.shape[0], spec.eval_batch());
    }

    let stats = eng.stats().unwrap();
    assert!(stats.executions >= 35);
    assert!(stats.compiled >= 3);
}

#[test]
fn cnn6_train_and_quant_eval() {
    let eng = engine();
    let spec = eng.manifest().model("cnn6").unwrap().clone();
    let sess = eng.create_session("cnn6", init_params(&spec.params, 2)).unwrap();
    let data = SynthVision::new(7);
    let (x, y) = data.batch(0, spec.train_batch());
    let tb = eng.register_batch(vec![x, y]).unwrap();
    let l0 = eng.train_step(sess, tb, 0.05).unwrap();
    for _ in 0..4 {
        eng.train_step(sess, tb, 0.05).unwrap();
    }
    let l1 = eng.train_step(sess, tb, 0.05).unwrap();
    assert!(l1 < l0, "{l1} !< {l0}");

    let (xe, ye) = data.batch(50_000, spec.eval_batch());
    let eb = eng.register_batch(vec![xe, ye]).unwrap();
    let n = spec.n_quant_layers();
    let (lq, cq) = eng.eval(sess, Some(QuantParams::passthrough(n)), eb).unwrap();
    assert!(lq.is_finite());
    assert!(cq >= 0.0);
}

#[test]
fn ncf_hitrate_paths() {
    let eng = engine();
    let spec = eng.manifest().model("ncf").unwrap().clone();
    let sess = eng.create_session("ncf", init_params(&spec.params, 3)).unwrap();
    let data = lapq::data::ncf::SynthNcf::new(11, 2000, 1000, 8);

    let hr_spec = &spec.input_spec["hitrate"];
    let nb = hr_spec[0].shape[0];
    let (u, p, negs) = data.eval_batch(0, nb);
    let hb = eng.register_batch(vec![u, p, negs]).unwrap();

    let hits = eng.hitrate(sess, None, hb).unwrap();
    assert!((0.0..=nb as f32).contains(&hits));

    let n = spec.n_quant_layers();
    let hits_q = eng.hitrate(sess, Some(QuantParams::passthrough(n)), hb).unwrap();
    assert_eq!(hits, hits_q);

    // train a bit; BCE loss drops
    let tb_spec = &spec.input_spec["train"];
    let (u, i, l) = data.train_batch(0, tb_spec[0].shape[0], 4);
    let tb = eng.register_batch(vec![u, i, l]).unwrap();
    let l0 = eng.train_step(sess, tb, 0.5).unwrap();
    for _ in 0..20 {
        eng.train_step(sess, tb, 0.5).unwrap();
    }
    let l1 = eng.train_step(sess, tb, 0.5).unwrap();
    assert!(l1 < l0, "{l1} !< {l0}");
}

#[test]
fn error_paths_are_errors() {
    let eng = engine();
    // wrong param count
    assert!(eng.create_session("cnn6", vec![]).is_err());
    // wrong shape
    let spec = eng.manifest().model("mlp3").unwrap().clone();
    let mut params = init_params(&spec.params, 1);
    params[0] = HostTensor::zeros(vec![2, 2]);
    assert!(eng.create_session("mlp3", params).is_err());
    // unknown model
    assert!(eng.create_session("nope", vec![]).is_err());
    // unknown session / batch ids
    assert!(eng.train_step(999, 999, 0.1).is_err());
}
