//! Integration: the TCP job service end-to-end — bind, serve, submit a
//! quantization job over the wire, read the structured response.

use lapq::coordinator::jobs::Runner;
use lapq::coordinator::service::{request, Service};
use lapq::runtime::EngineHandle;
use lapq::util::json::Json;

#[test]
fn service_roundtrip() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let service = Service::bind("127.0.0.1:0").unwrap();
    let addr = service.addr;

    let server = std::thread::spawn(move || {
        let mut runner = Runner::new(eng);
        service.serve(&mut runner, 4).unwrap();
    });

    // ping
    let pong = request(&addr, &Json::obj(vec![("cmd", Json::Str("ping".into()))])).unwrap();
    assert_eq!(pong.req("ok").as_bool(), Some(true));
    assert_eq!(pong.req("pong").as_bool(), Some(true));

    // models
    let models = request(&addr, &Json::obj(vec![("cmd", Json::Str("models".into()))])).unwrap();
    let names: Vec<&str> =
        models.req("models").as_arr().unwrap().iter().filter_map(|j| j.as_str()).collect();
    assert!(names.contains(&"mlp3"));

    // bad command: structured error, connection stays usable
    let bad = request(&addr, &Json::obj(vec![("cmd", Json::Str("nope".into()))])).unwrap();
    assert_eq!(bad.req("ok").as_bool(), Some(false));
    assert!(bad.req("error").as_str().unwrap().contains("unknown"));

    // quantize job over the wire (fast config)
    let job = Json::obj(vec![
        ("cmd", Json::Str("quantize".into())),
        ("model", Json::Str("mlp3".into())),
        ("train_steps", Json::Num(40.0)),
        ("lr", Json::Num(0.1)),
        ("val_size", Json::Num(512.0)),
        ("bits_w", Json::Num(8.0)),
        ("bits_a", Json::Num(8.0)),
        ("method", Json::Str("mmse".into())),
    ]);
    let resp = request(&addr, &job).unwrap();
    assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
    let result = resp.req("result");
    assert_eq!(result.req("model").as_str(), Some("mlp3"));
    let fp32 = result.req("fp32_metric").as_f64().unwrap();
    let quant = result.req("quant_metric").as_f64().unwrap();
    assert!((0.0..=1.0).contains(&fp32));
    assert!(quant >= fp32 - 0.05, "8/8 should be near-lossless: {quant} vs {fp32}");

    server.join().unwrap();
}
