//! Integration: the TCP job service end-to-end — bind, serve, submit
//! quantization / pack / infer jobs over the wire, read the structured
//! responses, and verify that malformed input never kills a connection.
//! The concurrent pool server (`lapq::serve`) is exercised against the
//! blocking service as its bit-for-bit reference, plus the overload
//! shed path.

use lapq::config::{BitSpec, ExperimentConfig, Method, ServeCfg};
use lapq::coordinator::jobs::Runner;
use lapq::coordinator::service::{request, Service};
use lapq::runtime::EngineHandle;
use lapq::serve::PoolServer;
use lapq::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

#[test]
fn service_roundtrip() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let service = Service::bind("127.0.0.1:0").unwrap();
    let addr = service.addr;

    let server = std::thread::spawn(move || {
        let mut runner = Runner::new(eng);
        service.serve(&mut runner, 4).unwrap();
    });

    // ping
    let pong = request(&addr, &Json::obj(vec![("cmd", Json::Str("ping".into()))])).unwrap();
    assert_eq!(pong.req("ok").as_bool(), Some(true));
    assert_eq!(pong.req("pong").as_bool(), Some(true));

    // models
    let models = request(&addr, &Json::obj(vec![("cmd", Json::Str("models".into()))])).unwrap();
    let names: Vec<&str> =
        models.req("models").as_arr().unwrap().iter().filter_map(|j| j.as_str()).collect();
    assert!(names.contains(&"mlp3"));

    // bad command: structured error, connection stays usable
    let bad = request(&addr, &Json::obj(vec![("cmd", Json::Str("nope".into()))])).unwrap();
    assert_eq!(bad.req("ok").as_bool(), Some(false));
    assert!(bad.req("error").as_str().unwrap().contains("unknown"));

    // quantize job over the wire (fast config)
    let job = Json::obj(vec![
        ("cmd", Json::Str("quantize".into())),
        ("model", Json::Str("mlp3".into())),
        ("train_steps", Json::Num(40.0)),
        ("lr", Json::Num(0.1)),
        ("val_size", Json::Num(512.0)),
        ("bits_w", Json::Num(8.0)),
        ("bits_a", Json::Num(8.0)),
        ("method", Json::Str("mmse".into())),
    ]);
    let resp = request(&addr, &job).unwrap();
    assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
    let result = resp.req("result");
    assert_eq!(result.req("model").as_str(), Some("mlp3"));
    let fp32 = result.req("fp32_metric").as_f64().unwrap();
    let quant = result.req("quant_metric").as_f64().unwrap();
    assert!((0.0..=1.0).contains(&fp32));
    assert!(quant >= fp32 - 0.05, "8/8 should be near-lossless: {quant} vs {fp32}");
    // the calibration layer mask rides along in the response
    let active_w = result.req("active_w").as_arr().unwrap();
    assert_eq!(active_w.len(), 3);

    server.join().unwrap();
}

/// Regression: a malformed JSON line or unknown `cmd` must produce
/// `{"ok":false,"error":...}` and keep the *same* connection serving.
#[test]
fn malformed_requests_keep_the_connection_alive() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let service = Service::bind("127.0.0.1:0").unwrap();
    let addr = service.addr;

    let server = std::thread::spawn(move || {
        let mut runner = Runner::new(eng);
        service.serve(&mut runner, 5).unwrap();
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut roundtrip = |line: &str| -> Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.parse::<Json>().expect("structured response")
    };

    // not JSON at all
    let r = roundtrip("this is { not json");
    assert_eq!(r.req("ok").as_bool(), Some(false));
    assert!(r.req("error").as_str().unwrap().contains("bad request"));

    // unknown command: the typed error carries the offending cmd back
    let r = roundtrip("{\"cmd\":\"frobnicate\"}");
    assert_eq!(r.req("ok").as_bool(), Some(false));
    assert_eq!(r.req("error").as_str(), Some("unknown_cmd"));
    assert_eq!(r.req("cmd").as_str(), Some("frobnicate"));

    // missing command
    let r = roundtrip("{\"x\":1}");
    assert_eq!(r.req("ok").as_bool(), Some(false));

    // a failing job (unknown model) — still a structured error
    let r = roundtrip("{\"cmd\":\"quantize\",\"model\":\"nope\"}");
    assert_eq!(r.req("ok").as_bool(), Some(false));

    // ...and the very same connection still answers pings
    let r = roundtrip("{\"cmd\":\"ping\"}");
    assert_eq!(r.req("ok").as_bool(), Some(true));
    assert_eq!(r.req("pong").as_bool(), Some(true));

    server.join().unwrap();
}

/// Long calibrations are observable over the wire: `"stream":true`
/// interleaves `{"event":...}` frames (phase starts/ends, degenerate
/// warnings, throttled evals) before the final `{"ok":...}` response —
/// and `joint=nm` is selectable end-to-end through the protocol.
#[test]
fn quantize_streams_calib_events() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let service = Service::bind("127.0.0.1:0").unwrap();
    let addr = service.addr;

    let server = std::thread::spawn(move || {
        let mut runner = Runner::new(eng);
        service.serve(&mut runner, 1).unwrap();
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let req = Json::obj(vec![
        ("cmd", Json::Str("quantize".into())),
        ("stream", Json::Bool(true)),
        ("model", Json::Str("mlp3".into())),
        ("train_steps", Json::Num(40.0)),
        ("lr", Json::Num(0.1)),
        ("val_size", Json::Num(512.0)),
        ("bits_w", Json::Num(4.0)),
        ("bits_a", Json::Num(4.0)),
        ("method", Json::Str("lapq".into())),
        (
            "lapq",
            Json::obj(vec![("joint", Json::Str("nm".into())), ("max_evals", Json::Num(60.0))]),
        ),
    ]);
    writer.write_all(req.dump().as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();

    // Read frames until the final {"ok":...} response arrives.
    let mut events: Vec<Json> = Vec::new();
    let mut final_resp: Option<Json> = None;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let j = line.unwrap().parse::<Json>().expect("every frame is JSON");
        if j.get("ok").is_some() {
            final_resp = Some(j);
            break;
        }
        assert!(j.get("event").is_some(), "non-event frame before the response: {j:?}");
        events.push(j);
    }

    // At least the init and joint phase boundaries must have streamed.
    let kinds: Vec<String> = events
        .iter()
        .map(|e| e.req("event").as_str().unwrap_or_default().to_string())
        .collect();
    assert!(kinds.iter().any(|k| k == "phase_start"), "{kinds:?}");
    assert!(kinds.iter().any(|k| k == "phase_end"), "{kinds:?}");
    let phases: Vec<&str> =
        events.iter().filter_map(|e| e.get("phase").and_then(|p| p.as_str())).collect();
    assert!(phases.contains(&"init"), "{phases:?}");
    assert!(phases.contains(&"joint:nelder-mead"), "nm must run: {phases:?}");

    // ...and the final response reports the alternative optimizer plus a
    // per-phase trace and a lossless config echo.
    let resp = final_resp.expect("final response after events");
    assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
    let result = resp.req("result");
    assert_eq!(result.req("joint").as_str(), Some("NelderMead"));
    let trace = result.req("trace").as_arr().unwrap();
    assert!(trace.len() >= 2, "trace: {trace:?}");
    assert_eq!(trace[0].req("phase").as_str(), Some("init"));
    let echoed = lapq::config::ExperimentConfig::from_json(result.req("config")).unwrap();
    assert_eq!(echoed.lapq.joint.optimizer, lapq::config::JointOpt::NelderMead);
    assert_eq!(echoed.lapq.joint.max_evals, 60);

    server.join().unwrap();
}

/// The serving loop: pack an INT8 mlp3 over the wire, then stream
/// predictions from the cached artifact.
#[test]
fn pack_and_infer_over_the_wire() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let service = Service::bind("127.0.0.1:0").unwrap();
    let addr = service.addr;

    let server = std::thread::spawn(move || {
        let mut runner = Runner::new(eng);
        service.serve(&mut runner, 3).unwrap();
    });

    // infer before any pack: structured error, service keeps going
    let miss = request(
        &addr,
        &Json::obj(vec![
            ("cmd", Json::Str("infer".into())),
            ("key", Json::Str("mlp3".into())),
            ("x", Json::Arr(vec![Json::arr_f32(&[0.0; 64])])),
        ]),
    )
    .unwrap();
    assert_eq!(miss.req("ok").as_bool(), Some(false));

    let packed = request(
        &addr,
        &Json::obj(vec![
            ("cmd", Json::Str("pack".into())),
            ("model", Json::Str("mlp3".into())),
            ("train_steps", Json::Num(40.0)),
            ("lr", Json::Num(0.1)),
            ("val_size", Json::Num(512.0)),
            ("bits_w", Json::Num(8.0)),
            ("bits_a", Json::Num(8.0)),
            ("method", Json::Str("mmse".into())),
        ]),
    )
    .unwrap();
    assert_eq!(packed.req("ok").as_bool(), Some(true), "{packed:?}");
    let key = packed.req("packed").req("key").as_str().unwrap().to_string();
    let f32_bytes = packed.req("packed").req("f32_bytes").as_f64().unwrap();
    let packed_bytes = packed.req("packed").req("packed_bytes").as_f64().unwrap();
    assert!(packed_bytes < f32_bytes);

    // two feature rows -> two predictions from the integer engine
    let rows = vec![Json::arr_f32(&[0.25; 64]), Json::arr_f32(&[-0.25; 64])];
    let infer = request(
        &addr,
        &Json::obj(vec![
            ("cmd", Json::Str("infer".into())),
            ("key", Json::Str(key)),
            ("x", Json::Arr(rows)),
        ]),
    )
    .unwrap();
    assert_eq!(infer.req("ok").as_bool(), Some(true), "{infer:?}");
    let result = infer.req("result");
    assert_eq!(result.req("rows").as_f64(), Some(2.0));
    assert_eq!(result.req("logits").as_arr().unwrap().len(), 2);
    assert_eq!(result.req("logits").as_arr().unwrap()[0].as_arr().unwrap().len(), 16);
    assert_eq!(result.req("predictions").as_arr().unwrap().len(), 2);

    server.join().unwrap();
}

fn fast_pack_cfg() -> ExperimentConfig {
    ExperimentConfig {
        model: "mlp3".into(),
        train_steps: 40,
        lr: 0.1,
        val_size: 512,
        bits: BitSpec::new(8, 8),
        method: Method::Mmse,
        ..Default::default()
    }
}

fn infer_request(key: &str, row: &[f32]) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("infer".into())),
        ("key", Json::Str(key.into())),
        ("x", Json::Arr(vec![Json::arr_f32(row)])),
    ])
}

/// The concurrency contract: ≥8 simultaneous connections issuing infer
/// against a preloaded model all succeed, and every response is
/// **bit-for-bit identical** to the same request served by the blocking
/// sequential service over the same packed artifact.
#[test]
fn concurrent_infer_matches_sequential_bit_for_bit() {
    let eng = EngineHandle::start_default().expect("engine boots");
    // `io` comes from the default (LAPQ_SERVE_IO in CI's second pass),
    // so this bit-for-bit contract pins both transports.
    let scfg = ServeCfg {
        workers: 8,
        batch_window_ms: 2.0,
        max_batch: 16,
        queue_bound: 64,
        registry_cap: 4,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng.clone(), scfg).unwrap();
    let key = server.preload(std::slice::from_ref(&fast_pack_cfg())).unwrap().remove(0);
    let registry = server.registry();
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(8).unwrap());

    // Sequential reference: the blocking Service over a Runner sharing
    // the same engine and the same packed artifact.
    let seq = Service::bind("127.0.0.1:0").unwrap();
    let seq_addr = seq.addr;
    let seq_thread = std::thread::spawn(move || {
        let mut runner = Runner::with_registry(eng, registry);
        seq.serve(&mut runner, 8).unwrap();
    });

    let reqs: Vec<Json> = (0..8)
        .map(|i: usize| {
            let row: Vec<f32> = (0..64).map(|j| ((i * 17 + j) % 9) as f32 * 0.1 - 0.4).collect();
            infer_request(&key, &row)
        })
        .collect();

    // Ground truth, one request at a time through the blocking path.
    let expected: Vec<String> = reqs
        .iter()
        .map(|r| {
            let resp = request(&seq_addr, r).unwrap();
            assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
            resp.req("result").req("logits").dump()
        })
        .collect();
    seq_thread.join().unwrap();

    // 8 simultaneous clients against the pool (barrier-released so the
    // micro-batcher actually sees them together).
    let barrier = Arc::new(Barrier::new(reqs.len()));
    let mut handles = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        let r = r.clone();
        let b = barrier.clone();
        handles.push(std::thread::spawn(move || {
            b.wait();
            let resp = request(&addr, &r).unwrap();
            assert_eq!(resp.req("ok").as_bool(), Some(true), "client {i}: {resp:?}");
            (i, resp.req("result").req("logits").dump())
        }));
    }
    for h in handles {
        let (i, logits) = h.join().unwrap();
        // f64 text is shortest-roundtrip, so identical text <=> identical bits
        assert_eq!(logits, expected[i], "client {i}: batched != sequential");
    }
    pool.join().unwrap();
}

/// Admission control: with the single worker parked on a connection and
/// the queue bound at 1, a third connection is shed with the typed
/// `{"ok":false,"error":"overloaded","retry_after_ms":..}` response —
/// while the admitted connections still complete (graceful drain).
#[test]
fn overload_sheds_with_typed_response() {
    let eng = EngineHandle::start_default().expect("engine boots");
    // Pinned to the threads transport: the choreography below parks the
    // single blocking worker on a partial line, which is meaningless
    // for the reactor (it never blocks on a read) — the reactor's shed
    // paths are pinned by tests/event_serve.rs instead.
    let scfg = ServeCfg {
        workers: 1,
        batch_window_ms: 0.0,
        max_batch: 1,
        queue_bound: 1,
        registry_cap: 4,
        io: lapq::config::IoMode::Threads,
        ..Default::default()
    };
    let server = PoolServer::bind("127.0.0.1:0", eng, scfg).unwrap();
    let addr = server.addr;
    let pool = std::thread::spawn(move || server.serve(3).unwrap());

    // Generous read timeouts so a missed expectation fails the test
    // cleanly instead of deadlocking the CI job on a blocked read.
    let timeout = Some(Duration::from_secs(120));

    // A parks the single worker deterministically: a partial request
    // line (no newline) keeps the worker blocked in read_line until the
    // test releases it — no dependence on how fast a real job runs.
    let a = TcpStream::connect(addr).unwrap();
    a.set_read_timeout(timeout).unwrap();
    let mut aw = a.try_clone().unwrap();
    aw.write_all(b"{\"cmd\":\"ping\"}").unwrap(); // note: no '\n'
    aw.flush().unwrap();
    std::thread::sleep(Duration::from_millis(300)); // worker picks A up

    // B fills the single queue slot...
    let b = TcpStream::connect(addr).unwrap();
    b.set_read_timeout(timeout).unwrap();
    std::thread::sleep(Duration::from_millis(150));

    // ...so C bounces off the bound with the typed shed response.
    let c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(timeout).unwrap();
    let mut cr = BufReader::new(c);
    let mut line = String::new();
    cr.read_line(&mut line).unwrap();
    let shed = line.parse::<Json>().expect("shed response is JSON");
    assert_eq!(shed.req("ok").as_bool(), Some(false), "{shed:?}");
    assert_eq!(shed.req("error").as_str(), Some("overloaded"), "{shed:?}");
    assert!(shed.req("retry_after_ms").as_f64().unwrap() >= 0.0, "{shed:?}");

    // Release A: complete its request line; it still gets a real reply...
    aw.write_all(b"\n").unwrap();
    aw.flush().unwrap();
    let mut ar = BufReader::new(a);
    let mut aline = String::new();
    ar.read_line(&mut aline).unwrap();
    assert_eq!(aline.parse::<Json>().unwrap().req("pong").as_bool(), Some(true));
    drop(ar);
    drop(aw);

    // ...and the queued B is served after A closes, not dropped.
    let mut bw = b.try_clone().unwrap();
    bw.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    bw.flush().unwrap();
    let mut br = BufReader::new(b);
    let mut bline = String::new();
    br.read_line(&mut bline).unwrap();
    assert_eq!(bline.parse::<Json>().unwrap().req("pong").as_bool(), Some(true));
    drop(br);
    drop(bw);
    pool.join().unwrap();
}
