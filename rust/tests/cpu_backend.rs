//! Integration: the pure-Rust CPU backend against the host-side
//! quantization reference — fake-quant parity, idempotence through the
//! eval path, and backend bookkeeping.

use lapq::quant::quantizer::fake_quant;
use lapq::quant::GridKind;
use lapq::runtime::{EngineHandle, QuantParams};
use lapq::tensor::init::init_params;
use lapq::tensor::HostTensor;

fn mlp_session(eng: &EngineHandle, seed: u64) -> (lapq::runtime::SessionId, Vec<HostTensor>) {
    let spec = eng.manifest().model("mlp3").unwrap().clone();
    let params = init_params(&spec.params, seed);
    let sess = eng.create_session("mlp3", params.clone()).unwrap();
    (sess, params)
}

fn mlp_batch(eng: &EngineHandle, n: usize) -> lapq::runtime::BatchId {
    let data = lapq::data::vision::SynthVision::new(5);
    let (x, y) = data.batch_features(0, n, 64);
    eng.register_batch(vec![x, y]).unwrap()
}

/// Weight fake-quant inside the backend must match `quant::quantizer`
/// exactly: evaluating original weights under (dw, qmw) equals evaluating
/// host-side quantize→dequantize'd weights in FP32.
#[test]
fn weight_fake_quant_matches_host_reference() {
    let eng = EngineHandle::cpu().unwrap();
    let (sess, params) = mlp_session(&eng, 11);
    let batch = mlp_batch(&eng, 128);
    let spec = eng.manifest().model("mlp3").unwrap().clone();
    let n = spec.n_quant_layers();

    // per-layer min-max-ish steps over the weight tensors
    let mut q = QuantParams::passthrough(n);
    let qmax = GridKind::Signed.qmax(4);
    for (i, ql) in spec.quant_layers.iter().enumerate() {
        let w = params[ql.weight_param].f();
        let absmax = w.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        q.dw[i] = absmax / qmax;
        q.qmw[i] = qmax;
    }
    let (loss_backend, correct_backend) = eng.eval(sess, Some(q.clone()), batch).unwrap();

    // quantize the weights host-side with the reference quantizer
    let mut quantized = params.clone();
    for (i, ql) in spec.quant_layers.iter().enumerate() {
        let w = &quantized[ql.weight_param];
        let qw = fake_quant(w.f(), q.dw[i], q.qmw[i], GridKind::Signed);
        quantized[ql.weight_param] = HostTensor::f32(w.shape.clone(), qw);
    }
    eng.set_params(sess, quantized).unwrap();
    let (loss_host, correct_host) = eng.eval(sess, None, batch).unwrap();

    assert_eq!(loss_backend, loss_host, "weight fake-quant diverges from quant::quantizer");
    assert_eq!(correct_backend, correct_host);
}

/// Quantize→dequantize is idempotent end-to-end: evaluating
/// already-quantized weights under the same (dw, qmw) changes nothing.
#[test]
fn roundtrip_idempotent_through_eval() {
    let eng = EngineHandle::cpu().unwrap();
    let (sess, params) = mlp_session(&eng, 13);
    let batch = mlp_batch(&eng, 128);
    let spec = eng.manifest().model("mlp3").unwrap().clone();
    let n = spec.n_quant_layers();

    let mut q = QuantParams::passthrough(n);
    for i in 0..n {
        q.dw[i] = 0.02;
        q.qmw[i] = 127.0;
    }
    let (l1, _) = eng.eval(sess, Some(q.clone()), batch).unwrap();

    let mut quantized = params.clone();
    for (i, ql) in spec.quant_layers.iter().enumerate() {
        let w = &quantized[ql.weight_param];
        let qw = fake_quant(w.f(), q.dw[i], q.qmw[i], GridKind::Signed);
        quantized[ql.weight_param] = HostTensor::f32(w.shape.clone(), qw);
    }
    eng.set_params(sess, quantized).unwrap();
    let (l2, _) = eng.eval(sess, Some(q), batch).unwrap();
    assert_eq!(l1, l2, "fake-quant not idempotent through the eval path");
}

/// Activation quantization must respect the per-layer grid sign: with an
/// unsigned-layer Δa engaged, loss moves; with Δa = 0 it is exact FP32.
#[test]
fn activation_quant_engages_per_layer() {
    let eng = EngineHandle::cpu().unwrap();
    let (sess, _) = mlp_session(&eng, 17);
    let batch = mlp_batch(&eng, 128);
    let n = eng.manifest().model("mlp3").unwrap().n_quant_layers();

    let (lf, _) = eng.eval(sess, None, batch).unwrap();
    let mut q = QuantParams::passthrough(n);
    q.da[1] = 0.4; // fc2 input is post-ReLU (unsigned grid)
    q.qma[1] = 3.0;
    let (lq, _) = eng.eval(sess, Some(q), batch).unwrap();
    assert!((lq - lf).abs() > 1e-5, "coarse activation quant had no effect: {lf} vs {lq}");
}

#[test]
fn backend_name_and_stats() {
    let eng = EngineHandle::cpu().unwrap();
    assert_eq!(eng.backend_name(), "cpu");
    let (sess, _) = mlp_session(&eng, 19);
    let batch = mlp_batch(&eng, 64);
    eng.eval(sess, None, batch).unwrap();
    let data = lapq::data::vision::SynthVision::new(5);
    let (x, _) = data.batch_features(0, 64, 64);
    let acts_batch = eng.register_batch(vec![x]).unwrap();
    eng.acts(sess, acts_batch).unwrap();
    let stats = eng.stats().unwrap();
    assert!(stats.executions >= 2);
    assert!(stats.compiled >= 2);
}
