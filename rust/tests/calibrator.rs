//! Integration: the composable calibration API — every method through the
//! `Calibrator` builder, joint optimizers interchangeable behind the
//! trait, observers seeing the event stream, and `joint=nm|cd` selectable
//! end-to-end from a config file.

use lapq::config::{BitSpec, ExperimentConfig, JointCfg, JointOpt, Method};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::stages::{joint_optimizer, BiasCorrection, LayerwiseLp};
use lapq::lapq::{CalibEvent, Calibrator, EventLog, NullObserver};
use lapq::runtime::EngineHandle;

fn fast_cfg(method: Method) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp3".into();
    cfg.train_steps = 60;
    cfg.lr = 0.1;
    cfg.calib_size = 512;
    cfg.val_size = 1024;
    cfg.bits = BitSpec::new(4, 4);
    cfg.method = method;
    cfg.lapq.joint.max_evals = 100;
    cfg.lapq.joint.iters = 1;
    cfg
}

/// The matrix: every `Method` on mlp3 yields finite losses, and whenever
/// the joint phase runs it cannot end above its own initialization.
#[test]
fn method_matrix_losses_finite_and_ordered() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let mut runner = Runner::new(eng);
    for method in Method::ALL {
        let cfg = fast_cfg(method);
        let res = runner.run(&cfg).unwrap();
        let o = &res.outcome;
        assert!(o.fp32_calib_loss.is_finite(), "{method:?}: fp32 loss");
        assert!(o.init_loss.is_finite(), "{method:?}: init loss");
        assert!(o.calib_loss.is_finite(), "{method:?}: calib loss");
        if method == Method::Lapq {
            assert!(o.joint_evals > 0, "{method:?}: joint phase must run");
            assert!(
                o.calib_loss <= o.init_loss + 1e-9,
                "{method:?}: joint {} above init {}",
                o.calib_loss,
                o.init_loss
            );
        } else {
            assert_eq!(o.joint_evals, 0, "{method:?}: baselines have no joint phase");
            assert_eq!(o.calib_loss, o.init_loss);
        }
        // every run records a per-phase trace, starting with init
        assert!(!o.trace.is_empty(), "{method:?}: trace missing");
        assert_eq!(o.trace[0].phase, "init");
    }
}

/// Powell / Nelder–Mead / coordinate descent are interchangeable through
/// the `JointOptimizer` trait: same fixed quadratic, same box, all three
/// land on the minimum.
#[test]
fn joint_optimizers_interchangeable_on_fixed_quadratic() {
    let target = [0.8, 1.5, 1.1, 0.6];
    for opt in JointOpt::ALL {
        let jc = JointCfg { optimizer: opt, iters: 8, max_evals: 6000 };
        let j = joint_optimizer(&jc);
        let mut evals = 0usize;
        let mut f = |x: &[f64]| -> anyhow::Result<f64> {
            evals += 1;
            Ok(x.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum())
        };
        let r = j.minimize(&[1.0; 4], &[0.3; 4], &[3.0; 4], &mut f).unwrap();
        assert!(r.fx < 1e-2, "{}: stalled at {}", j.name(), r.fx);
        assert!(r.evals <= jc.max_evals + 16, "{}: runaway evals {}", j.name(), r.evals);
        assert_eq!(r.evals, evals, "{}: eval accounting", j.name());
    }
}

/// The fallible objective signature: an engine error inside the joint
/// phase surfaces as `Err`, not as a silently-swallowed `+inf`.
#[test]
fn joint_objective_error_propagates() {
    for opt in JointOpt::ALL {
        let j = joint_optimizer(&JointCfg { optimizer: opt, ..Default::default() });
        let mut f = |_: &[f64]| -> anyhow::Result<f64> { anyhow::bail!("batch vanished") };
        let err = j.minimize(&[1.0; 2], &[0.5; 2], &[2.0; 2], &mut f).unwrap_err();
        assert!(format!("{err:#}").contains("batch vanished"), "{}", j.name());
    }
}

/// Observers see the full phase structure and benches get eval traces for
/// free; the outcome's trace mirrors the PhaseEnd events.
#[test]
fn observer_sees_phases_and_outcome_trace() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    let cfg = fast_cfg(Method::Lapq);
    let mut log = EventLog::default();
    let res = runner.run_observed(&cfg, &mut log).unwrap();

    let phases = log.phases();
    assert!(phases.contains(&"init"), "{phases:?}");
    assert!(phases.contains(&"joint:powell"), "{phases:?}");
    assert!(phases.contains(&"post:bias-correction"), "{phases:?}");
    assert!(log.evals() > 0, "eval events must stream");
    assert!(!log.degenerate(), "healthy run must not warn");

    // one PhaseEnd per PhaseStart, and the trace mirrors them in order
    let starts = log.events.iter().filter(|e| matches!(e, CalibEvent::PhaseStart { .. })).count();
    let ends = log.events.iter().filter(|e| matches!(e, CalibEvent::PhaseEnd { .. })).count();
    assert_eq!(starts, ends);
    assert_eq!(res.outcome.trace.len(), ends);
    let trace_phases: Vec<&str> = res.outcome.trace.iter().map(|t| t.phase).collect();
    assert_eq!(trace_phases, vec!["init", "joint:powell", "post:bias-correction"]);
    assert_eq!(res.outcome.trace[1].evals, res.outcome.joint_evals);
}

/// `joint=nm` and `joint=cd` are selectable end-to-end from a config
/// file: load → calibrate → the alternative optimizer actually runs and
/// still ends at-or-below its init.
#[test]
fn alternative_joint_optimizers_from_config_file() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    for (key, phase) in [("nm", "joint:nelder-mead"), ("cd", "joint:coordinate-descent")] {
        let path = std::env::temp_dir().join(format!("lapq_joint_{key}.json"));
        std::fs::write(
            &path,
            format!(
                r#"{{"model":"mlp3","train_steps":60,"lr":0.1,"val_size":512,
                     "bits_w":4,"bits_a":4,"method":"lapq",
                     "lapq":{{"joint":"{key}","max_evals":80}}}}"#
            ),
        )
        .unwrap();
        let cfg = ExperimentConfig::load(path.to_str().unwrap(), &[]).unwrap();
        assert_eq!(cfg.lapq.joint.max_evals, 80);

        let mut log = EventLog::default();
        let res = runner.run_observed(&cfg, &mut log).unwrap();
        assert!(log.phases().contains(&phase), "{key}: {:?}", log.phases());
        assert!(res.outcome.joint_evals > 0, "{key}: joint must run");
        assert!(
            res.outcome.calib_loss <= res.outcome.init_loss + 1e-9,
            "{key}: {} above {}",
            res.outcome.calib_loss,
            res.outcome.init_loss
        );
        let _ = std::fs::remove_file(&path);
    }
}

/// An explicitly composed calibrator (builder, not `from_config`) runs
/// end-to-end through the Runner.
#[test]
fn explicit_builder_composition_runs() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    let mut cfg = fast_cfg(Method::Lapq);
    cfg.lapq.joint.optimizer = JointOpt::CoordinateDescent;
    let cal = Calibrator::builder()
        .init(LayerwiseLp::fixed(vec![2.0, 4.0]))
        .joint_cfg(&cfg.lapq.joint)
        .post(BiasCorrection)
        .build();
    let res = runner.run_with(&cfg, &cal, &mut NullObserver).unwrap();
    assert!(res.outcome.calib_loss.is_finite());
    assert!(res.outcome.joint_evals > 0);
    assert!((0.0..=1.0).contains(&res.quant_metric));
}
