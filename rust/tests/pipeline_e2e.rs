//! Integration: the full LAPQ pipeline on the fast mlp3 model — phases,
//! baselines, ablation hooks and coordinator state management compose.

use lapq::config::{BitSpec, ExperimentConfig, Method};
use lapq::coordinator::jobs::Runner;
use lapq::lapq::InitKind;
use lapq::runtime::EngineHandle;

fn fast_cfg(method: Method, bits: BitSpec) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp3".into();
    cfg.train_steps = 60;
    cfg.lr = 0.1;
    cfg.calib_size = 512;
    cfg.val_size = 1024;
    cfg.bits = bits;
    cfg.method = method;
    cfg.lapq.joint.max_evals = 120;
    cfg.lapq.joint.iters = 1;
    cfg
}

#[test]
fn lapq_beats_or_matches_baselines_on_calib_loss() {
    let eng = EngineHandle::start_default().expect("engine boots");
    let mut runner = Runner::new(eng);
    let bits = BitSpec::new(4, 4);

    let lapq = runner.run(&fast_cfg(Method::Lapq, bits)).unwrap();
    let mmse = runner.run(&fast_cfg(Method::Mmse, bits)).unwrap();
    let minmax = runner.run(&fast_cfg(Method::MinMax, bits)).unwrap();

    // the joint optimizer directly minimizes calibration loss: it must not
    // be worse than the layer-wise baselines on its own objective
    assert!(
        lapq.outcome.calib_loss <= mmse.outcome.calib_loss + 1e-6,
        "lapq {} vs mmse {}",
        lapq.outcome.calib_loss,
        mmse.outcome.calib_loss
    );
    assert!(lapq.outcome.calib_loss <= minmax.outcome.calib_loss + 1e-6);

    // diagnostics populated
    assert!(lapq.outcome.p_star.is_some());
    assert!(lapq.outcome.joint_evals > 0);
    assert!(mmse.outcome.joint_evals == 0);

    // metrics are probabilities
    for r in [&lapq, &mmse, &minmax] {
        assert!((0.0..=1.0).contains(&r.fp32_metric));
        assert!((0.0..=1.0).contains(&r.quant_metric));
    }
    // quantized never beats FP32 by much (sanity)
    assert!(lapq.quant_metric <= lapq.fp32_metric + 0.05);
}

#[test]
fn joint_phase_improves_over_init() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    let cfg = fast_cfg(Method::Lapq, BitSpec::new(4, 4));

    // Table-3 machinery: random init, no joint vs joint
    let rand_only = runner.run_with_init(&cfg, InitKind::Random(5), false).unwrap();
    let rand_joint = runner.run_with_init(&cfg, InitKind::Random(5), true).unwrap();
    assert!(
        rand_joint.outcome.calib_loss <= rand_only.outcome.calib_loss + 1e-9,
        "joint {} !<= init {}",
        rand_joint.outcome.calib_loss,
        rand_only.outcome.calib_loss
    );

    // LW+QA init should already be decent: better than random init
    let lwqa = runner.run_with_init(&cfg, InitKind::LapqQuadratic, false).unwrap();
    assert!(lwqa.outcome.init_loss <= rand_only.outcome.init_loss + 1e-9);
}

#[test]
fn fp32_bits_skip_that_side() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    // weights FP32, acts 8-bit: all dw must be 0
    let res = runner.run(&fast_cfg(Method::Mmse, BitSpec::new(32, 8))).unwrap();
    assert!(res.outcome.quant.dw.iter().all(|&d| d == 0.0));
    assert!(res.outcome.quant.da.iter().any(|&d| d > 0.0));
    // 8-bit quantization is near-lossless
    assert!(res.quant_metric >= res.fp32_metric - 0.02, "{res:?}");
}

#[test]
fn exclude_first_last_respected() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    let cfg = fast_cfg(Method::Mmse, BitSpec::new(4, 4));
    let res = runner.run(&cfg).unwrap();
    let dw = &res.outcome.quant.dw;
    assert_eq!(dw[0], 0.0);
    assert_eq!(*dw.last().unwrap(), 0.0);
    assert!(dw[1] > 0.0);

    let mut cfg_all = cfg.clone();
    cfg_all.lapq.exclude_first_last = false;
    let res_all = runner.run(&cfg_all).unwrap();
    assert!(res_all.outcome.quant.dw[0] > 0.0);
}

#[test]
fn int8_mlp_smoke_near_lossless() {
    // INT8/INT8 LAPQ on the MLP: the full pipeline (layer-wise -> quad fit
    // -> Powell) must complete and stay near the FP32 metric.
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    let res = runner.run(&fast_cfg(Method::Lapq, BitSpec::new(8, 8))).unwrap();
    assert!(res.outcome.joint_evals > 0);
    assert!(res.outcome.calib_loss.is_finite());
    assert!(res.quant_metric >= res.fp32_metric - 0.03, "{res:?}");
}

#[test]
fn ncf_pipeline_hitrate() {
    let eng = EngineHandle::start_default().unwrap();
    let mut runner = Runner::new(eng);
    let mut cfg = fast_cfg(Method::Mmse, BitSpec::new(8, 8));
    cfg.model = "ncf".into();
    cfg.train_steps = 80;
    cfg.lr = 0.5;
    cfg.calib_size = 4096;
    let res = runner.run(&cfg).unwrap();
    // hit-rate in [0,1]; 8/8 close to fp32
    assert!((0.0..=1.0).contains(&res.quant_metric));
    assert!(res.quant_metric >= res.fp32_metric - 0.1);
}
