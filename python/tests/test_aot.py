"""AOT path: HLO-text emission and manifest ABI consistency."""

import json
import os

import jax
import pytest

from compile.aot import (
    _entry_arg_specs,
    build_model,
    entries_for,
    to_hlo_text,
)
from compile.models import REGISTRY

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_emission_smoke(tmp_path):
    """mlp3 end-to-end lowering produces parseable-looking HLO text."""
    man = build_model(REGISTRY["mlp3"], str(tmp_path))
    for entry, info in man["entries"].items():
        text = (tmp_path / info["file"]).read_text()
        assert text.startswith("HloModule"), entry
        assert "ENTRY" in text, entry
        # 64-bit ids would break xla_extension 0.5.1; text ids are small.
        assert info["n_args"] >= 1


@pytest.mark.parametrize("name", list(REGISTRY))
def test_entry_arg_counts(name):
    m = REGISTRY[name]
    n_p = len(m.param_specs)
    n_q = len(m.quant_layers)
    specs = _entry_arg_specs(m, "fwd_quant")
    n_batch = len(m.input_spec["eval"])
    assert len(specs) == n_p + 4 + n_batch
    for s in specs[n_p : n_p + 4]:
        assert s.shape == (n_q,)
    train = _entry_arg_specs(m, "train_step")
    assert len(train) == 2 * n_p + len(m.input_spec["train"]) + 1


@pytest.mark.parametrize("name", list(REGISTRY))
def test_manifest_matches_models(name):
    """If artifacts/ exists, its manifest must agree with the live ABI."""
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        man = json.load(f)
    assert name in man["models"]
    mm = man["models"][name]
    m = REGISTRY[name]
    assert len(mm["params"]) == len(m.param_specs)
    assert len(mm["quant_layers"]) == len(m.quant_layers)
    for entry in entries_for(m):
        assert entry in mm["entries"]
        assert mm["entries"][entry]["n_args"] == len(_entry_arg_specs(m, entry))
        f = os.path.join(ARTIFACTS, mm["entries"][entry]["file"])
        assert os.path.exists(f)
