"""Layer-2 model zoo: shapes, training smoke, quantization behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import grid_qmax
from compile.models import REGISTRY
from compile.models import ncf as ncf_mod
from compile.models.common import (
    init_params,
    make_acts,
    make_fwd_fp32,
    make_fwd_quant,
    make_train_step,
)

KEY = jax.random.PRNGKey(42)
VISION = ["mlp3", "cnn6", "resmini", "dwsep"]


def _vision_batch(model, b):
    shape, _ = model.input_spec["eval"]["x"]
    x = jax.random.normal(KEY, (b, *shape[1:]))
    n_cls = model.param_specs[-1].shape[0]
    y = jax.random.randint(KEY, (b,), 0, n_cls)
    return x, y


def _quant_vecs(model, bits_w=4, bits_a=4):
    n = len(model.quant_layers)
    dw = jnp.full((n,), 0.02)
    qmw = jnp.full((n,), grid_qmax(bits_w, True))
    da = jnp.full((n,), 0.05)
    qma = jnp.asarray(
        [grid_qmax(bits_a, q.act_signed) for q in model.quant_layers], jnp.float32
    )
    return dw, qmw, da, qma


@pytest.mark.parametrize("name", VISION)
def test_param_specs_consistent(name):
    m = REGISTRY[name]
    params = init_params(m, KEY)
    assert len(params) == len(m.param_specs)
    for p, spec in zip(params, m.param_specs):
        assert p.shape == tuple(spec.shape)
    # every quant layer points at a real weight tensor
    for q in m.quant_layers:
        assert len(m.param_specs[q.weight_param].shape) >= 2


@pytest.mark.parametrize("name", VISION)
def test_acts_align_with_quant_layers(name):
    m = REGISTRY[name]
    params = init_params(m, KEY)
    b = m.input_spec["eval"]["x"][0][0]
    x, _ = _vision_batch(m, b)
    acts = jax.jit(make_acts(m))(*params, x)
    assert len(acts) == len(m.quant_layers)
    for a in acts:
        assert a.shape[0] == b


@pytest.mark.parametrize("name", VISION)
def test_tiny_delta_quant_close_to_fp32(name):
    """As Δ -> small with a huge grid, the quantized loss converges to FP32."""
    m = REGISTRY[name]
    params = init_params(m, KEY)
    b = m.input_spec["eval"]["x"][0][0]
    x, y = _vision_batch(m, b)
    n = len(m.quant_layers)
    dw = jnp.full((n,), 1e-4)
    qmw = jnp.full((n,), 2.0**20)
    da = jnp.full((n,), 1e-4)
    qma = jnp.full((n,), 2.0**20)
    lq, cq = jax.jit(make_fwd_quant(m))(*params, dw, qmw, da, qma, x, y)
    lf, cf = jax.jit(make_fwd_fp32(m))(*params, x, y)
    np.testing.assert_allclose(lq, lf, rtol=1e-2, atol=1e-3)
    assert abs(float(cq) - float(cf)) <= b * 0.02 + 1


@pytest.mark.parametrize("name", VISION)
def test_zero_delta_equals_fp32_exactly(name):
    m = REGISTRY[name]
    params = init_params(m, KEY)
    b = m.input_spec["eval"]["x"][0][0]
    x, y = _vision_batch(m, b)
    n = len(m.quant_layers)
    z = jnp.zeros((n,))
    q = jnp.full((n,), 7.0)
    lq, cq = jax.jit(make_fwd_quant(m))(*params, z, q, z, q, x, y)
    lf, cf = jax.jit(make_fwd_fp32(m))(*params, x, y)
    np.testing.assert_allclose(lq, lf, rtol=1e-5, atol=1e-6)
    assert float(cq) == float(cf)


@pytest.mark.parametrize("name", ["mlp3", "cnn6"])
def test_train_step_learns(name):
    """A few SGD steps on a fixed batch must reduce the loss (overfit smoke)."""
    m = REGISTRY[name]
    params = init_params(m, KEY)
    bt = m.input_spec["train"]["x"][0][0]
    x, y = _vision_batch(m, bt)
    mom = tuple(jnp.zeros_like(p) for p in params)
    step = jax.jit(make_train_step(m))
    n = len(params)
    first = None
    for i in range(60):
        out = step(*params, *mom, x, y, jnp.float32(0.1))
        params, mom, loss = out[:n], out[n : 2 * n], out[-1]
        if first is None:
            first = float(loss)
    assert float(loss) < first - 0.1, (first, float(loss))


def test_coarse_quant_perturbs_loss_more():
    """2-bit min-max steps shift the loss away from FP32 more than 8-bit ones
    (paper §3.2: coarser grids sit in steeper territory)."""
    m = REGISTRY["cnn6"]
    params = init_params(m, KEY)
    x, y = _vision_batch(m, 256)
    n = len(m.quant_layers)
    fwd = jax.jit(make_fwd_quant(m))
    l_fp = float(jax.jit(make_fwd_fp32(m))(*params, x, y)[0])

    def minmax_loss(bits):
        qmw = jnp.full((n,), grid_qmax(bits, True))
        qma = jnp.asarray(
            [grid_qmax(bits, q.act_signed) for q in m.quant_layers], jnp.float32
        )
        dw = jnp.asarray(
            [float(jnp.max(jnp.abs(params[q.weight_param]))) for q in m.quant_layers]
        ) / qmw
        da = jnp.full((n,), 6.0) / qma  # generous activation range
        return float(fwd(*params, dw, qmw, da, qma, x, y)[0])

    dev8 = abs(minmax_loss(8) - l_fp)
    dev2 = abs(minmax_loss(2) - l_fp)
    assert dev2 > dev8, (dev2, dev8)


# ---------------------------------------------------------------------------
# NCF
# ---------------------------------------------------------------------------


def test_ncf_shapes_and_hitrate_bounds():
    m = REGISTRY["ncf"]
    params = init_params(m, KEY)
    u = jax.random.randint(KEY, (256,), 0, ncf_mod.N_USERS)
    pos = jax.random.randint(KEY, (256,), 0, ncf_mod.N_ITEMS)
    negs = jax.random.randint(KEY, (256, 99), 0, ncf_mod.N_ITEMS)
    (hits,) = jax.jit(ncf_mod.make_hitrate(m))(*params, u, pos, negs)
    assert 0.0 <= float(hits) <= 256.0


def test_ncf_quant_hitrate_matches_fp32_at_zero_delta():
    m = REGISTRY["ncf"]
    params = init_params(m, KEY)
    n = len(m.quant_layers)
    z, q = jnp.zeros((n,)), jnp.full((n,), 7.0)
    u = jax.random.randint(KEY, (256,), 0, ncf_mod.N_USERS)
    pos = jax.random.randint(KEY, (256,), 0, ncf_mod.N_ITEMS)
    negs = jax.random.randint(KEY, (256, 99), 0, ncf_mod.N_ITEMS)
    (h_fp,) = jax.jit(ncf_mod.make_hitrate(m))(*params, u, pos, negs)
    (h_q,) = jax.jit(ncf_mod.make_hitrate_quant(m))(*params, z, q, z, q, u, pos, negs)
    assert float(h_fp) == float(h_q)


def test_ncf_train_learns():
    m = REGISTRY["ncf"]
    params = init_params(m, KEY)
    bt = m.input_spec["train"]["users"][0][0]
    u = jax.random.randint(KEY, (bt,), 0, ncf_mod.N_USERS)
    it = jax.random.randint(KEY, (bt,), 0, ncf_mod.N_ITEMS)
    lab = jax.random.bernoulli(KEY, 0.4, (bt,)).astype(jnp.float32)
    mom = tuple(jnp.zeros_like(p) for p in params)
    step = jax.jit(make_train_step(m))
    n = len(params)
    first = None
    for _ in range(60):
        out = step(*params, *mom, u, it, lab, jnp.float32(0.5))
        params, mom, loss = out[:n], out[n : 2 * n], out[-1]
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.9
