"""Layer-1 kernel correctness: Pallas vs. pure-jnp oracle.

The hypothesis sweeps are the "shapes/dtypes fuzzing" contract: any shape,
any step size, any grid bound must match ``ref.py`` to float32 tolerance.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant, grid_qmax, lp_error, lp_error_sum, quant_matmul
from compile.kernels.ref import (
    fake_quant_ref,
    lp_error_ref,
    lp_error_sum_ref,
    quant_matmul_ref,
)

RNG = np.random.default_rng(1234)


def _rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize(
    "shape", [(7,), (128,), (1000,), (37, 53), (3, 3, 16, 32), (2, 32, 32, 3)]
)
def test_fake_quant_matches_ref(signed, bits, shape):
    x = _rand(shape)
    qmax = grid_qmax(bits, signed)
    for delta in (0.0, 0.01, 0.1, 0.7):
        got = fake_quant(x, delta, qmax, signed=signed)
        want = fake_quant_ref(x, delta, qmax, signed=signed)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_fake_quant_zero_delta_is_identity():
    x = _rand((257,))
    np.testing.assert_array_equal(fake_quant(x, 0.0, 7.0), x)


def test_fake_quant_idempotent():
    """Q(Q(x)) == Q(x): quantized values lie exactly on the grid."""
    x = _rand((513,))
    once = fake_quant(x, 0.07, 7.0)
    twice = fake_quant(once, 0.07, 7.0)
    np.testing.assert_allclose(once, twice, rtol=0, atol=1e-7)


def test_fake_quant_error_bound():
    """|Q(x)-x| <= Δ/2 inside the clip range (round-to-nearest)."""
    delta, qmax = 0.05, 7.0
    x = jnp.linspace(-delta * qmax, delta * qmax, 1001)
    err = jnp.abs(fake_quant(x, delta, qmax) - x)
    assert float(jnp.max(err)) <= delta / 2 + 1e-6


def test_fake_quant_clips():
    delta, qmax = 0.1, 7.0
    x = jnp.asarray([100.0, -100.0, 0.69, -0.74])
    y = fake_quant(x, delta, qmax)
    np.testing.assert_allclose(y[:2], [0.7, -0.7], atol=1e-6)
    y_u = fake_quant(x, delta, 15.0, signed=False)
    np.testing.assert_allclose(y_u[1], 0.0, atol=1e-6)  # unsigned clips negatives


def test_fake_quant_level_count():
    """An M-bit signed grid uses at most 2^M - 1 distinct levels."""
    x = _rand((4096,), scale=3.0)
    for bits in (2, 3, 4):
        y = fake_quant(x, 0.2, grid_qmax(bits, True))
        assert len(np.unique(np.asarray(y))) <= 2**bits - 1


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 3000),
    delta=st.floats(1e-4, 2.0),
    bits=st.integers(2, 8),
    signed=st.booleans(),
    scale=st.floats(0.01, 10.0),
)
def test_fake_quant_hypothesis(n, delta, bits, signed, scale):
    x = jnp.asarray(np.random.default_rng(n).normal(size=(n,)).astype(np.float32) * scale)
    qmax = grid_qmax(bits, signed)
    got = fake_quant(x, delta, qmax, signed=signed)
    want = fake_quant_ref(x, delta, qmax, signed=signed)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# lp_error
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [1.0, 2.0, 2.4, 3.0, 3.5, 4.0])
def test_lp_error_matches_ref(p):
    x = _rand((777,))
    got = lp_error(x, 0.05, 7.0, p)
    want = lp_error_ref(x, 0.05, 7.0, p)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_lp_error_zero_delta_is_zero():
    x = _rand((100,))
    assert float(lp_error_sum(x, 0.0, 7.0, 2.0)) == 0.0


def test_lp_error_padding_invariant():
    """Block padding must not contribute to the reduction."""
    x = _rand((1,))  # heavy padding case
    got = lp_error_sum(x, 0.3, 3.0, 2.0)
    want = lp_error_sum_ref(x, 0.3, 3.0, 2.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 2000),
    delta=st.floats(1e-3, 1.0),
    p=st.floats(1.0, 5.0),
    signed=st.booleans(),
)
def test_lp_error_hypothesis(n, delta, p, signed):
    x = jnp.asarray(np.random.default_rng(n + 7).normal(size=(n,)).astype(np.float32))
    got = lp_error_sum(x, delta, 7.0, p, signed=signed)
    want = lp_error_sum_ref(x, delta, 7.0, p, signed=signed)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)


def test_lp_error_tradeoff_has_interior_minimum():
    """Fig. 4: e_p(Δ) decreases then increases -> interior optimum."""
    x = _rand((4096,))
    deltas = np.linspace(0.005, 1.0, 60)
    errs = [float(lp_error(x, d, 7.0, 2.0)) for d in deltas]
    best = int(np.argmin(errs))
    assert 0 < best < len(deltas) - 1


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("signed_a", [True, False])
@pytest.mark.parametrize("mkn", [(4, 8, 4), (64, 128, 10), (45, 70, 33), (256, 96, 16)])
def test_quant_matmul_matches_ref(signed_a, mkn):
    m, k, n = mkn
    a, b = _rand((m, k)), _rand((k, n))
    got = quant_matmul(a, b, 0.05, 15.0, 0.02, 7.0, signed_a=signed_a)
    want = quant_matmul_ref(a, b, 0.05, 15.0, 0.02, 7.0, signed_a=signed_a)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_quant_matmul_passthrough_matches_plain():
    a, b = _rand((16, 32)), _rand((32, 8))
    got = quant_matmul(a, b, 0.0, 7.0, 0.0, 7.0)
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 160),
    n=st.integers(1, 48),
    da=st.floats(0.0, 0.5),
    dw=st.floats(0.0, 0.5),
)
def test_quant_matmul_hypothesis(m, k, n, da, dw):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    got = quant_matmul(a, b, da, 15.0, dw, 7.0)
    want = quant_matmul_ref(a, b, da, 15.0, dw, 7.0)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
