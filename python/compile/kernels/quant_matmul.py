"""Layer-1 Pallas kernel: quantized matmul.

``qmm(a, b) = FQ_{Δa,qa}(a) @ FQ_{Δw,qw}(b)`` — both operands are
fake-quantized *inside* the tile so the (TPU) MXU consumes quantized
operands straight from VMEM without an HBM round-trip.  The dense layers of
every Layer-2 model route through this kernel, which is how the paper's
compute hot-spot lowers into the model HLO.

Grid is (M/bm, N/bn, K/bk) with accumulation over the K axis; tiles are
lane-aligned and zero-padded (FQ(0) = 0, so padding is exact).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _fq(x, d, qmax, lo_signed: bool):
    safe = jnp.where(d > 0.0, d, 1.0)
    q = jnp.round(x / safe)
    lo = -qmax if lo_signed else jnp.float32(0.0)
    q = jnp.clip(q, lo, qmax)
    return jnp.where(d > 0.0, q * safe, x)


def _qmm_kernel(a_ref, b_ref, da_ref, qa_ref, dw_ref, qw_ref, o_ref, *, signed_a: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = _fq(a_ref[...], da_ref[0], qa_ref[0], lo_signed=signed_a)
    b = _fq(b_ref[...], dw_ref[0], qw_ref[0], lo_signed=True)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("signed_a",))
def quant_matmul(a, b, d_act, qmax_act, d_w, qmax_w, signed_a: bool = True):
    """Fake-quantized ``a @ b`` for 2-D operands.

    ``d_act``/``d_w`` are runtime scalar step sizes (0 = pass-through);
    ``qmax_*`` the integer grid bounds.  ``signed_a`` selects the activation
    grid sign (images / embeddings are signed, post-ReLU tensors unsigned).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(_ceil_to(m, 8), 128), min(_ceil_to(k, 128), 512), min(_ceil_to(n, 128), 128)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1)
    sspec = pl.BlockSpec((1,), lambda i, j, l: (0,))

    out = pl.pallas_call(
        functools.partial(_qmm_kernel, signed_a=signed_a),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            sspec,
            sspec,
            sspec,
            sspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p, scal(d_act), scal(qmax_act), scal(d_w), scal(qmax_w))
    return out[:m, :n]
