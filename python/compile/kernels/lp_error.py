"""Layer-1 Pallas kernel: blockwise L_p quantization-error reduction.

Computes ``sum(|Q_{Δ,qmax}(x) - x|^p)`` (Eq. 12 of the paper, without the
final ``1/p`` root, which the caller applies).  Used by the layer-wise phase
of LAPQ and by the MMSE baseline; the Layer-3 coordinator golden-sections
over Δ with this as the inner objective.

Blocks reduce into per-block partial sums; the final reduction happens in
plain XLA outside the kernel.  Zero padding is invariant: ``Q(0) = 0`` so
padded elements contribute nothing.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fake_quant import _block_layout


def _lp_kernel(x_ref, d_ref, q_ref, p_ref, o_ref, *, signed: bool):
    x = x_ref[...]
    d = d_ref[0]
    qmax = q_ref[0]
    p = p_ref[0]
    safe = jnp.where(d > 0.0, d, 1.0)
    qv = jnp.round(x / safe)
    lo = -qmax if signed else jnp.float32(0.0)
    qv = jnp.clip(qv, lo, qmax)
    y = jnp.where(d > 0.0, qv * safe, x)
    err = jnp.abs(y - x)
    o_ref[0, 0] = jnp.sum(err**p)


@functools.partial(jax.jit, static_argnames=("signed",))
def lp_error_sum(x, delta, qmax, p, signed: bool = True):
    """``sum(|Q(x) - x|^p)`` as a scalar float32."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    block, n_blocks = _block_layout(n)
    pad = block * n_blocks - n
    tiled = jnp.pad(flat, (0, pad)).reshape(n_blocks, block)
    d = jnp.asarray(delta, jnp.float32).reshape(1)
    q = jnp.asarray(qmax, jnp.float32).reshape(1)
    pv = jnp.asarray(p, jnp.float32).reshape(1)

    partials = pl.pallas_call(
        functools.partial(_lp_kernel, signed=signed),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        interpret=True,
    )(tiled, d, q, pv)
    return jnp.sum(partials)


def lp_error(x, delta, qmax, p, signed: bool = True):
    """Eq. 12: ``(sum |Q(x)-x|^p)^{1/p}``."""
    return lp_error_sum(x, delta, qmax, p, signed=signed) ** (1.0 / p)
