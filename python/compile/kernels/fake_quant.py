"""Layer-1 Pallas kernel: symmetric uniform fake-quantization (Eq. 1 of the
LAPQ paper).

``Q_{Δ,qmax}(x) = clip(round(x / Δ), lo, qmax) · Δ`` with ``lo = -qmax`` for
signed (weight) grids and ``lo = 0`` for unsigned (post-ReLU activation)
grids.  ``Δ`` and ``qmax`` are *runtime* scalars, so a single lowered HLO
serves every bitwidth and every candidate step size the Layer-3 optimizer
proposes.  ``Δ == 0`` bypasses quantization (the paper's "do not quantize
first/last layer" convention is expressed by the coordinator passing 0).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the tensor is tiled into
VMEM-resident blocks; Δ/qmax are broadcast scalars (SMEM); the body is pure
VPU element-wise work.  On this image Pallas MUST run with
``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic custom-calls),
so the BlockSpec schedule documents the TPU plan while numerics are
validated on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane-aligned block width.  On a real TPU this would be an (8k, 128) VMEM
# tile; under interpret=True the block is simply the unit of the grid loop,
# so we keep the number of grid steps small (<= MAX_BLOCKS) to bound the
# size of the lowered HLO while-loop on the CPU hot path.
LANE = 128
MAX_BLOCKS = 8


def _block_layout(n: int) -> tuple[int, int]:
    """Choose (block_len, n_blocks) for a flat tensor of ``n`` elements."""
    block = max(LANE, -(-n // MAX_BLOCKS))  # ceil-div, then lane-align up
    block = -(-block // LANE) * LANE
    n_blocks = -(-n // block)
    return block, n_blocks


def _fq_kernel(x_ref, d_ref, q_ref, o_ref, *, signed: bool):
    """One VMEM block of quantize-dequantize."""
    x = x_ref[...]
    d = d_ref[0]
    qmax = q_ref[0]
    # Guard Δ == 0 (pass-through layer): divide by a safe value, then select.
    safe = jnp.where(d > 0.0, d, 1.0)
    q = jnp.round(x / safe)
    lo = -qmax if signed else jnp.float32(0.0)
    q = jnp.clip(q, lo, qmax)
    y = q * safe
    o_ref[...] = jnp.where(d > 0.0, y, x)


@functools.partial(jax.jit, static_argnames=("signed",))
def fake_quant(x, delta, qmax, signed: bool = True):
    """Quantize-dequantize ``x`` on a uniform grid of step ``delta``.

    Args:
      x: any-shape float32 tensor.
      delta: scalar float32 step size; ``0`` disables quantization.
      qmax: scalar float32, largest integer level (``2^{M-1}-1`` signed,
        ``2^M - 1`` unsigned for ``M`` bits).
      signed: weight grid (symmetric) vs. post-ReLU activation grid.

    Returns:
      Tensor of the same shape/dtype as ``x``.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    block, n_blocks = _block_layout(n)
    pad = block * n_blocks - n
    flat = jnp.pad(flat, (0, pad))
    tiled = flat.reshape(n_blocks, block)
    d = jnp.asarray(delta, jnp.float32).reshape(1)
    q = jnp.asarray(qmax, jnp.float32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_fq_kernel, signed=signed),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
        interpret=True,
    )(tiled, d, q)
    return out.reshape(-1)[:n].reshape(shape)


def grid_qmax(bits: int, signed: bool = True) -> float:
    """Largest integer level of an ``bits``-bit uniform grid."""
    return float(2 ** (bits - 1) - 1) if signed else float(2**bits - 1)
