"""Layer-1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from .fake_quant import fake_quant, grid_qmax
from .lp_error import lp_error, lp_error_sum
from .quant_matmul import quant_matmul

__all__ = ["fake_quant", "grid_qmax", "lp_error", "lp_error_sum", "quant_matmul"]
