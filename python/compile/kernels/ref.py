"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: pytest (and the hypothesis sweeps)
assert the Pallas kernels match these to float32 tolerance across shapes,
step sizes, grid bounds and signedness.  They are also what the Rust
`quant::quantizer` module mirrors bit-for-bit on the host side.
"""

import jax.numpy as jnp


def fake_quant_ref(x, delta, qmax, signed: bool = True):
    """Reference quantize-dequantize (paper Eq. 1, runtime-Δ form)."""
    delta = jnp.asarray(delta, jnp.float32)
    qmax = jnp.asarray(qmax, jnp.float32)
    safe = jnp.where(delta > 0.0, delta, 1.0)
    q = jnp.round(x / safe)
    lo = -qmax if signed else jnp.float32(0.0)
    q = jnp.clip(q, lo, qmax)
    return jnp.where(delta > 0.0, q * safe, x)


def lp_error_sum_ref(x, delta, qmax, p, signed: bool = True):
    """Reference ``sum(|Q(x) - x|^p)``."""
    y = fake_quant_ref(x, delta, qmax, signed=signed)
    return jnp.sum(jnp.abs(y - x) ** jnp.asarray(p, jnp.float32))


def lp_error_ref(x, delta, qmax, p, signed: bool = True):
    """Reference Eq. 12 ``(sum |Q(x)-x|^p)^{1/p}``."""
    return lp_error_sum_ref(x, delta, qmax, p, signed=signed) ** (1.0 / p)


def quant_matmul_ref(a, b, d_act, qmax_act, d_w, qmax_w, signed_a: bool = True):
    """Reference fake-quantized matmul."""
    aq = fake_quant_ref(a, d_act, qmax_act, signed=signed_a)
    bq = fake_quant_ref(b, d_w, qmax_w, signed=True)
    return aq @ bq
