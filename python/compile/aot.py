"""AOT compiler: lower every Layer-2 entry point to HLO *text* artifacts.

This is the only place Python runs — once, at build time (`make artifacts`).
The Rust runtime loads the emitted ``artifacts/*.hlo.txt`` via
``HloModuleProto::from_text_file`` and executes them on the PJRT CPU client.

HLO **text** (not ``lowered.compile().serialize()`` / serialized protos) is
the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Alongside the HLO files we write ``manifest.json`` — the ABI contract the
Rust coordinator parses: parameter specs (shape/init), the quant-layer
table, and the exact argument/output shapes of every entry point.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .models import REGISTRY
from .models import ncf as ncf_mod
from .models.common import (
    make_acts,
    make_fwd_fp32,
    make_fwd_quant,
    make_train_step,
)

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[dtype])


def _param_specs(model):
    return [_spec(p.shape) for p in model.param_specs]


def _batch_specs(model, entry):
    return [_spec(s, d) for (s, d) in model.input_spec[entry].values()]


def _quant_vec_specs(model):
    n = len(model.quant_layers)
    return [_spec((n,)) for _ in range(4)]  # dw, qmw, da, qma


def _entry_arg_specs(model, entry):
    p = _param_specs(model)
    if entry == "train_step":
        return p + p + _batch_specs(model, "train") + [_spec(())]
    if entry == "fwd_quant":
        return p + _quant_vec_specs(model) + _batch_specs(model, "eval")
    if entry == "fwd_fp32":
        return p + _batch_specs(model, "eval")
    if entry == "acts":
        specs = _batch_specs(model, "eval")
        if model.task == "ncf":
            specs = specs[:2]  # users, items (drop labels)
        else:
            specs = specs[:1]  # x (drop y)
        return p + specs
    if entry == "hitrate":
        return p + _batch_specs(model, "hitrate")
    if entry == "hitrate_quant":
        return p + _quant_vec_specs(model) + _batch_specs(model, "hitrate")
    raise ValueError(entry)


def _entry_fn(model, entry):
    if entry == "train_step":
        return make_train_step(model)
    if entry == "fwd_quant":
        return make_fwd_quant(model)
    if entry == "fwd_fp32":
        return make_fwd_fp32(model)
    if entry == "acts":
        return make_acts(model)
    if entry == "hitrate":
        return ncf_mod.make_hitrate(model)
    if entry == "hitrate_quant":
        return ncf_mod.make_hitrate_quant(model)
    raise ValueError(entry)


def entries_for(model):
    base = ["train_step", "fwd_quant", "fwd_fp32", "acts"]
    if model.task == "ncf":
        base += ["hitrate", "hitrate_quant"]
    return base


def build_model(model, out_dir):
    """Lower all entry points of ``model``; return its manifest fragment."""
    man = {
        "task": model.task,
        "params": [
            {"name": p.name, "shape": list(p.shape), "init": p.init, "fan_in": p.fan_in}
            for p in model.param_specs
        ],
        "quant_layers": [
            {
                "name": q.name,
                "weight_param": q.weight_param,
                "act_signed": q.act_signed,
                "kind": q.kind,
            }
            for q in model.quant_layers
        ],
        # NOTE: emitted as an ordered *list* — argument order is ABI.
        "input_spec": {
            e: [{"name": k, "shape": list(s), "dtype": d} for k, (s, d) in spec.items()]
            for e, spec in model.input_spec.items()
        },
        "entries": {},
    }
    for entry in entries_for(model):
        fn = _entry_fn(model, entry)
        specs = _entry_arg_specs(model, entry)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{model.name}_{entry}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(o.shape), "dtype": "f32" if o.dtype == jnp.float32 else "i32"}
            for o in jax.eval_shape(fn, *specs)
        ]
        man["entries"][entry] = {
            "file": fname,
            "n_args": len(specs),
            "outputs": out_shapes,
        }
        print(f"  {fname}: {len(text) / 1e6:.2f} MB, {len(specs)} args, {len(out_shapes)} outputs")
    return man


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--model", default=None, help="build a single model")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"models": {}}
    for name, model in REGISTRY.items():
        if args.model and name != args.model:
            continue
        print(f"[aot] {name}")
        manifest["models"][name] = build_model(model, args.out)

    path = os.path.join(args.out, "manifest.json")
    # Merge with an existing manifest when building a subset.
    if args.model and os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        old["models"].update(manifest["models"])
        manifest = old
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {path}")


if __name__ == "__main__":
    main()
