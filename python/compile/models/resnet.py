"""resmini — mini residual network on 32x32x3 synthetic images.

Stand-in for ResNet-50/101: deeper (11 quant sites) with skip connections,
exercising the cross-layer coupling the paper attributes to depth
(Fig. A.1: adjacent layers interact most).  Two stages of two residual
blocks each, channel widths 16 -> 32.
"""

import jax
import jax.numpy as jnp

from .common import (
    Model,
    ParamSpec,
    QuantLayer,
    conv2d,
    dense,
    global_avg_pool,
    vision_loss_and_correct,
)

N_CLASSES = 10

PARAMS = [
    ParamSpec("stem_w", (3, 3, 3, 16), "he", 27),
    ParamSpec("stem_b", (16,), "zeros"),
    # stage 1: two residual blocks @16
    ParamSpec("s1b1c1_w", (3, 3, 16, 16), "he", 144),
    ParamSpec("s1b1c1_b", (16,), "zeros"),
    ParamSpec("s1b1c2_w", (3, 3, 16, 16), "he", 144),
    ParamSpec("s1b1c2_b", (16,), "zeros"),
    ParamSpec("s1b2c1_w", (3, 3, 16, 16), "he", 144),
    ParamSpec("s1b2c1_b", (16,), "zeros"),
    ParamSpec("s1b2c2_w", (3, 3, 16, 16), "he", 144),
    ParamSpec("s1b2c2_b", (16,), "zeros"),
    # downsample to 32 channels, stride 2
    ParamSpec("down_w", (3, 3, 16, 32), "he", 144),
    ParamSpec("down_b", (32,), "zeros"),
    # stage 2: two residual blocks @32
    ParamSpec("s2b1c1_w", (3, 3, 32, 32), "he", 288),
    ParamSpec("s2b1c1_b", (32,), "zeros"),
    ParamSpec("s2b1c2_w", (3, 3, 32, 32), "he", 288),
    ParamSpec("s2b1c2_b", (32,), "zeros"),
    ParamSpec("s2b2c1_w", (3, 3, 32, 32), "he", 288),
    ParamSpec("s2b2c1_b", (32,), "zeros"),
    ParamSpec("s2b2c2_w", (3, 3, 32, 32), "he", 288),
    ParamSpec("s2b2c2_b", (32,), "zeros"),
    ParamSpec("fc_w", (32, N_CLASSES), "glorot", 32),
    ParamSpec("fc_b", (N_CLASSES,), "zeros"),
]

QUANT_LAYERS = [
    QuantLayer("stem", 0, act_signed=True, kind="conv"),
    QuantLayer("s1b1c1", 2, act_signed=False, kind="conv"),
    QuantLayer("s1b1c2", 4, act_signed=False, kind="conv"),
    QuantLayer("s1b2c1", 6, act_signed=False, kind="conv"),
    QuantLayer("s1b2c2", 8, act_signed=False, kind="conv"),
    QuantLayer("down", 10, act_signed=False, kind="conv"),
    QuantLayer("s2b1c1", 12, act_signed=False, kind="conv"),
    QuantLayer("s2b1c2", 14, act_signed=False, kind="conv"),
    QuantLayer("s2b2c1", 16, act_signed=False, kind="conv"),
    QuantLayer("s2b2c2", 18, act_signed=False, kind="conv"),
    QuantLayer("fc", 20, act_signed=False, kind="dense"),
]


def _block(h, params, quant, pi, qi, tape):
    """Residual block: relu(conv) -> conv, + skip, relu."""
    w1, b1, w2, b2 = params[pi : pi + 4]
    y = jax.nn.relu(conv2d(h, w1, b1, quant, qi, act_signed=False, tape=tape))
    y = conv2d(y, w2, b2, quant, qi + 1, act_signed=False, tape=tape)
    return jax.nn.relu(h + y)


def apply(params, x, quant, tape=None):
    h = jax.nn.relu(conv2d(x, params[0], params[1], quant, 0, act_signed=True, tape=tape))
    h = _block(h, params, quant, 2, 1, tape)
    h = _block(h, params, quant, 6, 3, tape)
    h = jax.nn.relu(
        conv2d(h, params[10], params[11], quant, 5, act_signed=False, stride=2, tape=tape)
    )
    h = _block(h, params, quant, 12, 6, tape)
    h = _block(h, params, quant, 16, 8, tape)
    pooled = global_avg_pool(h)
    return dense(pooled, params[20], params[21], quant, 10, act_signed=False, tape=tape)


MODEL = Model(
    name="resmini",
    param_specs=PARAMS,
    quant_layers=QUANT_LAYERS,
    apply=apply,
    loss_and_correct=vision_loss_and_correct(apply),
    input_spec={
        "train": {"x": ((128, 32, 32, 3), "f32"), "y": ((128,), "i32")},
        "eval": {"x": ((256, 32, 32, 3), "f32"), "y": ((256,), "i32")},
    },
    task="vision",
)
