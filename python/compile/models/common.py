"""Shared Layer-2 machinery: quantized layer primitives, entry-point builders.

A ``Model`` couples

  * ``param_specs`` — names/shapes/init kinds, mirrored by the Rust
    coordinator's host-side parameter store (it initializes and owns the
    weights; Python never sees them at run time);
  * ``quant_layers`` — the per-layer quantization table (paper §3: one
    weight step Δw and one input-activation step Δa per layer);
  * ``apply`` — the forward pass, optionally quantized via the Layer-1
    Pallas kernels with *runtime* Δ vectors.

Entry points lowered by ``aot.py`` (argument order is the ABI the Rust
runtime relies on — see artifacts/manifest.json):

  train_step : [*params, *momentum, x, y, lr]          -> (*params', *mom', loss)
  fwd_quant  : [*params, dw, qmw, da, qma, x, y]       -> (loss, correct)
  fwd_fp32   : [*params, x, y]                         -> (loss, correct)
  acts       : [*params, x]                            -> (act_0, ..., act_{n-1})

where ``dw/qmw/da/qma`` are float32[n_quant_layers] vectors; entry ``i`` of
``dw`` equal to 0 disables weight quantization of layer ``i`` (ditto ``da``
for activations) — the first/last-layer convention is pure coordinator
policy, never baked into the graph.
"""

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import fake_quant, quant_matmul


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One host-owned parameter tensor."""

    name: str
    shape: tuple
    init: str  # "he" | "glorot" | "zeros" | "embed"
    fan_in: int = 0


@dataclasses.dataclass(frozen=True)
class QuantLayer:
    """One quantization site: a weight tensor + its input activation."""

    name: str
    weight_param: int  # index into param_specs
    act_signed: bool  # input activation grid sign (image/embedding vs ReLU)
    kind: str  # "conv" | "dense" | "dwconv" | "embed"


@dataclasses.dataclass(frozen=True)
class Model:
    name: str
    param_specs: Sequence[ParamSpec]
    quant_layers: Sequence[QuantLayer]
    # apply(params, inputs, quant) -> (logits, acts); quant is None (fp32)
    # or a 4-tuple (dw, qmw, da, qma) of f32[n] vectors.
    apply: Callable
    # loss_and_correct(params, quant, *batch) -> (loss, correct)
    loss_and_correct: Callable
    input_spec: dict  # name -> (shape, dtype) for one batch, per entry point
    task: str = "vision"  # "vision" | "ncf"


# ---------------------------------------------------------------------------
# Quantized layer primitives
# ---------------------------------------------------------------------------


def qdq_w(w, quant, i):
    """Fake-quantize weight tensor of quant-layer ``i`` (signed grid)."""
    if quant is None:
        return w
    dw, qmw, _, _ = quant
    return fake_quant(w, dw[i], qmw[i], signed=True)


def qdq_a(x, quant, i, signed):
    """Fake-quantize the input activation of quant-layer ``i``."""
    if quant is None:
        return x
    _, _, da, qma = quant
    return fake_quant(x, da[i], qma[i], signed=signed)


def dense(x, w, b, quant, i, act_signed, tape=None):
    """Quantized dense layer; routes through the Pallas quant_matmul kernel.

    ``tape`` (dict) records the FP32 input activation under the quant-layer
    index — the ``acts`` entry point uses it so that activation calibration
    data aligns 1:1 with the quant-layer table.
    """
    if tape is not None:
        tape[i] = x
    if quant is None:
        return x @ w + b
    dw, qmw, da, qma = quant
    return quant_matmul(x, w, da[i], qma[i], dw[i], qmw[i], signed_a=act_signed) + b


def conv2d(x, w, b, quant, i, act_signed, stride=1, groups=1, tape=None):
    """Quantized 3x3/1x1 conv (NHWC, HWIO, SAME)."""
    if tape is not None:
        tape[i] = x
    xq = qdq_a(x, quant, i, act_signed)
    wq = qdq_w(w, quant, i)
    y = lax.conv_general_dilated(
        xq,
        wq,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + b


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def softmax_xent(logits, y):
    """Mean cross-entropy over the batch; ``y`` int32 class ids."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def vision_loss_and_correct(apply):
    def f(params, quant, x, y):
        logits = apply(params, x, quant)
        loss = softmax_xent(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, correct

    return f


def bce_with_logits(logits, labels):
    """Numerically stable binary cross-entropy; labels float32 in {0,1}."""
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ---------------------------------------------------------------------------
# Entry-point builders (what aot.py lowers)
# ---------------------------------------------------------------------------


def make_train_step(model: Model, lr_wd: float = 1e-4, momentum: float = 0.9):
    """SGD-with-momentum step over the FP32 graph (quant=None).

    Flat ABI: [*params, *mom, *batch, lr] -> (*params', *mom', loss).
    """
    n = len(model.param_specs)

    def step(*args):
        params = tuple(args[:n])
        mom = tuple(args[n : 2 * n])
        *batch, lr = args[2 * n :]

        def loss_fn(ps):
            loss, _ = model.loss_and_correct(ps, None, *batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_mom = tuple(momentum * m + g + lr_wd * p for m, g, p in zip(mom, grads, params))
        new_params = tuple(p - lr * m for p, m in zip(params, new_mom))
        return (*new_params, *new_mom, loss)

    return step


def make_fwd_quant(model: Model):
    n = len(model.param_specs)

    def fwd(*args):
        params = tuple(args[:n])
        dw, qmw, da, qma = args[n : n + 4]
        batch = args[n + 4 :]
        loss, correct = model.loss_and_correct(params, (dw, qmw, da, qma), *batch)
        return loss, correct

    return fwd


def make_fwd_fp32(model: Model):
    n = len(model.param_specs)

    def fwd(*args):
        params = tuple(args[:n])
        batch = args[n:]
        loss, correct = model.loss_and_correct(params, None, *batch)
        return loss, correct

    return fwd


def make_acts(model: Model):
    """FP32 forward returning the input activation of every quant layer."""
    n = len(model.param_specs)

    def acts(*args):
        params = tuple(args[:n])
        inputs = args[n:]
        tape = {}
        arg = inputs if model.task == "ncf" else inputs[0]
        logits = model.apply(params, arg, None, tape=tape)
        # Anchor: depend on the logits so no parameter is dead — jax would
        # otherwise prune unused tail-layer weights from the lowered HLO
        # signature, breaking the positional ABI the Rust engine assembles.
        anchor = jnp.sum(logits) * 0.0
        return tuple(tape[i] + anchor for i in range(len(model.quant_layers)))

    return acts


# Init helpers shared by python tests (the Rust store re-implements these).


def init_params(model: Model, key):
    out = []
    for spec in model.param_specs:
        key, sub = jax.random.split(key)
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, jnp.float32))
        elif spec.init == "he":
            std = (2.0 / max(spec.fan_in, 1)) ** 0.5
            out.append(std * jax.random.normal(sub, spec.shape, jnp.float32))
        elif spec.init == "glorot":
            fan_out = spec.shape[-1]
            std = (2.0 / (spec.fan_in + fan_out)) ** 0.5
            out.append(std * jax.random.normal(sub, spec.shape, jnp.float32))
        elif spec.init == "embed":
            out.append(0.05 * jax.random.normal(sub, spec.shape, jnp.float32))
        else:
            raise ValueError(spec.init)
    return tuple(out)
