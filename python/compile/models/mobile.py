"""dwsep — depthwise-separable CNN (MobileNet-V2 stand-in).

Depthwise convolutions concentrate few weights per channel with widely
varying per-channel ranges, which is exactly what makes MobileNets fragile
under post-training quantization and what makes bias correction matter
(paper Table 4).  Quant sites: stem + 3x (depthwise, pointwise) + fc = 8.
"""

import jax
import jax.numpy as jnp

from .common import (
    Model,
    ParamSpec,
    QuantLayer,
    conv2d,
    dense,
    global_avg_pool,
    vision_loss_and_correct,
)

N_CLASSES = 10

PARAMS = [
    ParamSpec("stem_w", (3, 3, 3, 16), "he", 27),
    ParamSpec("stem_b", (16,), "zeros"),
    ParamSpec("dw1_w", (3, 3, 1, 16), "he", 9),
    ParamSpec("dw1_b", (16,), "zeros"),
    ParamSpec("pw1_w", (1, 1, 16, 32), "he", 16),
    ParamSpec("pw1_b", (32,), "zeros"),
    ParamSpec("dw2_w", (3, 3, 1, 32), "he", 9),
    ParamSpec("dw2_b", (32,), "zeros"),
    ParamSpec("pw2_w", (1, 1, 32, 64), "he", 32),
    ParamSpec("pw2_b", (64,), "zeros"),
    ParamSpec("dw3_w", (3, 3, 1, 64), "he", 9),
    ParamSpec("dw3_b", (64,), "zeros"),
    ParamSpec("pw3_w", (1, 1, 64, 64), "he", 64),
    ParamSpec("pw3_b", (64,), "zeros"),
    ParamSpec("fc_w", (64, N_CLASSES), "glorot", 64),
    ParamSpec("fc_b", (N_CLASSES,), "zeros"),
]

QUANT_LAYERS = [
    QuantLayer("stem", 0, act_signed=True, kind="conv"),
    QuantLayer("dw1", 2, act_signed=False, kind="dwconv"),
    QuantLayer("pw1", 4, act_signed=False, kind="conv"),
    QuantLayer("dw2", 6, act_signed=False, kind="dwconv"),
    QuantLayer("pw2", 8, act_signed=False, kind="conv"),
    QuantLayer("dw3", 10, act_signed=False, kind="dwconv"),
    QuantLayer("pw3", 12, act_signed=False, kind="conv"),
    QuantLayer("fc", 14, act_signed=False, kind="dense"),
]


def apply(params, x, quant, tape=None):
    h = jax.nn.relu(conv2d(x, params[0], params[1], quant, 0, act_signed=True, tape=tape))
    h = jax.nn.relu(
        conv2d(h, params[2], params[3], quant, 1, act_signed=False, stride=2, groups=16, tape=tape)
    )
    h = jax.nn.relu(conv2d(h, params[4], params[5], quant, 2, act_signed=False, tape=tape))
    h = jax.nn.relu(
        conv2d(h, params[6], params[7], quant, 3, act_signed=False, stride=2, groups=32, tape=tape)
    )
    h = jax.nn.relu(conv2d(h, params[8], params[9], quant, 4, act_signed=False, tape=tape))
    h = jax.nn.relu(
        conv2d(h, params[10], params[11], quant, 5, act_signed=False, groups=64, tape=tape)
    )
    h = jax.nn.relu(conv2d(h, params[12], params[13], quant, 6, act_signed=False, tape=tape))
    pooled = global_avg_pool(h)
    return dense(pooled, params[14], params[15], quant, 7, act_signed=False, tape=tape)


MODEL = Model(
    name="dwsep",
    param_specs=PARAMS,
    quant_layers=QUANT_LAYERS,
    apply=apply,
    loss_and_correct=vision_loss_and_correct(apply),
    input_spec={
        "train": {"x": ((128, 32, 32, 3), "f32"), "y": ((128,), "i32")},
        "eval": {"x": ((256, 32, 32, 3), "f32"), "y": ((256,), "i32")},
    },
    task="vision",
)
