"""mlp3 — 3-layer MLP on 64-d synthetic features (quickstart model).

Smallest member of the zoo; used by the quickstart example and as the fast
target for integration tests.  Quant layers: fc1, fc2, fc3 (fc1 input is the
signed feature vector, later inputs are post-ReLU / unsigned).
"""

from .common import (
    Model,
    ParamSpec,
    QuantLayer,
    dense,
    vision_loss_and_correct,
)

import jax
import jax.numpy as jnp

D_IN, H1, H2, N_CLASSES = 64, 128, 96, 16

PARAMS = [
    ParamSpec("fc1_w", (D_IN, H1), "he", D_IN),
    ParamSpec("fc1_b", (H1,), "zeros"),
    ParamSpec("fc2_w", (H1, H2), "he", H1),
    ParamSpec("fc2_b", (H2,), "zeros"),
    ParamSpec("fc3_w", (H2, N_CLASSES), "glorot", H2),
    ParamSpec("fc3_b", (N_CLASSES,), "zeros"),
]

QUANT_LAYERS = [
    QuantLayer("fc1", 0, act_signed=True, kind="dense"),
    QuantLayer("fc2", 2, act_signed=False, kind="dense"),
    QuantLayer("fc3", 4, act_signed=False, kind="dense"),
]


def apply(params, x, quant, tape=None):
    w1, b1, w2, b2, w3, b3 = params
    h = jax.nn.relu(dense(x, w1, b1, quant, 0, act_signed=True, tape=tape))
    h = jax.nn.relu(dense(h, w2, b2, quant, 1, act_signed=False, tape=tape))
    return dense(h, w3, b3, quant, 2, act_signed=False, tape=tape)


MODEL = Model(
    name="mlp3",
    param_specs=PARAMS,
    quant_layers=QUANT_LAYERS,
    apply=apply,
    loss_and_correct=vision_loss_and_correct(apply),
    input_spec={
        "train": {"x": ((128, D_IN), "f32"), "y": ((128,), "i32")},
        "eval": {"x": ((512, D_IN), "f32"), "y": ((512,), "i32")},
    },
    task="vision",
)
