"""Layer-2 model zoo (JAX graphs that call the Layer-1 Pallas kernels).

Each model module exposes a ``MODEL`` object (see ``common.Model``); the
registry below is what ``aot.py`` iterates to emit artifacts.
"""

from . import cnn, mlp, mobile, ncf, resnet
from .common import Model

REGISTRY = {
    m.name: m
    for m in [mlp.MODEL, cnn.MODEL, resnet.MODEL, mobile.MODEL, ncf.MODEL]
}

__all__ = ["REGISTRY", "Model"]
