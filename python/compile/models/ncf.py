"""ncf — Neural Collaborative Filtering (NCF-1B stand-in, paper §5.2).

GMF + MLP two-tower NCF [He et al. 2017] over synthetic implicit feedback.
Trained with BCE on sampled negatives; evaluated with the mlperf protocol
(hit-rate@10 against 99 sampled negatives), matching Table 2's metric.

Quant sites: 4 embedding tables (weight-only; Δa fixed to 0 by the
coordinator since their "input" is an index) + fc1 + fc2 + out = 7.
"""

import jax
import jax.numpy as jnp

from .common import (
    Model,
    ParamSpec,
    QuantLayer,
    bce_with_logits,
    dense,
    qdq_w,
)

N_USERS, N_ITEMS, DIM = 2000, 1000, 16

PARAMS = [
    ParamSpec("emb_gmf_u", (N_USERS, DIM), "embed"),
    ParamSpec("emb_gmf_i", (N_ITEMS, DIM), "embed"),
    ParamSpec("emb_mlp_u", (N_USERS, DIM), "embed"),
    ParamSpec("emb_mlp_i", (N_ITEMS, DIM), "embed"),
    ParamSpec("fc1_w", (2 * DIM, 32), "he", 2 * DIM),
    ParamSpec("fc1_b", (32,), "zeros"),
    ParamSpec("fc2_w", (32, 16), "he", 32),
    ParamSpec("fc2_b", (16,), "zeros"),
    ParamSpec("out_w", (DIM + 16, 1), "glorot", DIM + 16),
    ParamSpec("out_b", (1,), "zeros"),
]

QUANT_LAYERS = [
    QuantLayer("emb_gmf_u", 0, act_signed=True, kind="embed"),
    QuantLayer("emb_gmf_i", 1, act_signed=True, kind="embed"),
    QuantLayer("emb_mlp_u", 2, act_signed=True, kind="embed"),
    QuantLayer("emb_mlp_i", 3, act_signed=True, kind="embed"),
    QuantLayer("fc1", 4, act_signed=True, kind="dense"),
    QuantLayer("fc2", 6, act_signed=False, kind="dense"),
    QuantLayer("out", 8, act_signed=True, kind="dense"),
]


def _embed(table, idx, quant, i, tape):
    tq = qdq_w(table, quant, i)
    e = jnp.take(tq, idx, axis=0)
    if tape is not None:
        tape[i] = e  # record looked-up vectors (Δa stays 0 for embeds)
    return e


def apply(params, batch, quant, tape=None):
    """``batch = (users, items)`` int32 vectors -> logits (B,)."""
    users, items = batch
    gu, gi, mu, mi, w1, b1, w2, b2, wo, bo = params
    eg_u = _embed(gu, users, quant, 0, tape)
    eg_i = _embed(gi, items, quant, 1, tape)
    em_u = _embed(mu, users, quant, 2, tape)
    em_i = _embed(mi, items, quant, 3, tape)
    gmf = eg_u * eg_i
    h = jnp.concatenate([em_u, em_i], axis=-1)
    h = jax.nn.relu(dense(h, w1, b1, quant, 4, act_signed=True, tape=tape))
    h = jax.nn.relu(dense(h, w2, b2, quant, 5, act_signed=False, tape=tape))
    z = jnp.concatenate([gmf, h], axis=-1)
    return dense(z, wo, bo, quant, 6, act_signed=True, tape=tape)[:, 0]


def loss_and_correct(params, quant, users, items, labels):
    logits = apply(params, (users, items), quant)
    loss = bce_with_logits(logits, labels)
    pred = (logits > 0.0).astype(jnp.float32)
    correct = jnp.sum((pred == labels).astype(jnp.float32))
    return loss, correct


def make_hitrate(model):
    """mlperf NCF eval: hit-rate@10 with 99 sampled negatives.

    ABI: [*params, users(B,), pos(B,), negs(B,99)] -> (hits,)
    """
    n = len(model.param_specs)

    def hitrate(*args):
        params = tuple(args[:n])
        users, pos, negs = args[n], args[n + 1], args[n + 2]
        b, k = negs.shape
        all_items = jnp.concatenate([pos[:, None], negs], axis=1)  # (B, 1+K)
        users_rep = jnp.repeat(users[:, None], k + 1, axis=1).reshape(-1)
        logits = apply(params, (users_rep, all_items.reshape(-1)), None)
        scores = logits.reshape(b, k + 1)
        rank = jnp.sum((scores[:, 1:] > scores[:, :1]).astype(jnp.int32), axis=1)
        return (jnp.sum((rank < 10).astype(jnp.float32)),)

    return hitrate


def make_hitrate_quant(model):
    """Quantized hit-rate@10: [*params, dw, qmw, da, qma, users, pos, negs]."""
    n = len(model.param_specs)

    def hitrate(*args):
        params = tuple(args[:n])
        quant = args[n : n + 4]
        users, pos, negs = args[n + 4], args[n + 5], args[n + 6]
        b, k = negs.shape
        all_items = jnp.concatenate([pos[:, None], negs], axis=1)
        users_rep = jnp.repeat(users[:, None], k + 1, axis=1).reshape(-1)
        logits = apply(params, (users_rep, all_items.reshape(-1)), quant)
        scores = logits.reshape(b, k + 1)
        rank = jnp.sum((scores[:, 1:] > scores[:, :1]).astype(jnp.int32), axis=1)
        return (jnp.sum((rank < 10).astype(jnp.float32)),)

    return hitrate


MODEL = Model(
    name="ncf",
    param_specs=PARAMS,
    quant_layers=QUANT_LAYERS,
    apply=apply,
    loss_and_correct=loss_and_correct,
    input_spec={
        "train": {
            "users": ((2048,), "i32"),
            "items": ((2048,), "i32"),
            "labels": ((2048,), "f32"),
        },
        "eval": {
            "users": ((4096,), "i32"),
            "items": ((4096,), "i32"),
            "labels": ((4096,), "f32"),
        },
        "hitrate": {
            "users": ((256,), "i32"),
            "pos": ((256,), "i32"),
            "negs": ((256, 99), "i32"),
        },
    },
    task="ncf",
)
