"""cnn6 — 6-layer plain CNN on 32x32x3 synthetic images.

Stand-in for ResNet-18 in the paper's tables: the main vehicle for
Tables 1/3/4, the loss-surface figures (first two conv layers), and the
Hessian/curvature analysis.  Quant layers: conv1..conv5 + fc (6 sites).
"""

import jax
import jax.numpy as jnp

from .common import (
    Model,
    ParamSpec,
    QuantLayer,
    conv2d,
    dense,
    global_avg_pool,
    vision_loss_and_correct,
)

N_CLASSES = 10

PARAMS = [
    ParamSpec("conv1_w", (3, 3, 3, 16), "he", 27),
    ParamSpec("conv1_b", (16,), "zeros"),
    ParamSpec("conv2_w", (3, 3, 16, 32), "he", 144),
    ParamSpec("conv2_b", (32,), "zeros"),
    ParamSpec("conv3_w", (3, 3, 32, 32), "he", 288),
    ParamSpec("conv3_b", (32,), "zeros"),
    ParamSpec("conv4_w", (3, 3, 32, 64), "he", 288),
    ParamSpec("conv4_b", (64,), "zeros"),
    ParamSpec("conv5_w", (3, 3, 64, 64), "he", 576),
    ParamSpec("conv5_b", (64,), "zeros"),
    ParamSpec("fc_w", (64, N_CLASSES), "glorot", 64),
    ParamSpec("fc_b", (N_CLASSES,), "zeros"),
]

QUANT_LAYERS = [
    QuantLayer("conv1", 0, act_signed=True, kind="conv"),
    QuantLayer("conv2", 2, act_signed=False, kind="conv"),
    QuantLayer("conv3", 4, act_signed=False, kind="conv"),
    QuantLayer("conv4", 6, act_signed=False, kind="conv"),
    QuantLayer("conv5", 8, act_signed=False, kind="conv"),
    QuantLayer("fc", 10, act_signed=False, kind="dense"),
]


def apply(params, x, quant, tape=None):
    (w1, b1, w2, b2, w3, b3, w4, b4, w5, b5, wf, bf) = params
    h = jax.nn.relu(conv2d(x, w1, b1, quant, 0, act_signed=True, tape=tape))
    h = jax.nn.relu(conv2d(h, w2, b2, quant, 1, act_signed=False, stride=2, tape=tape))
    h = jax.nn.relu(conv2d(h, w3, b3, quant, 2, act_signed=False, tape=tape))
    h = jax.nn.relu(conv2d(h, w4, b4, quant, 3, act_signed=False, stride=2, tape=tape))
    h = jax.nn.relu(conv2d(h, w5, b5, quant, 4, act_signed=False, tape=tape))
    pooled = global_avg_pool(h)
    return dense(pooled, wf, bf, quant, 5, act_signed=False, tape=tape)


MODEL = Model(
    name="cnn6",
    param_specs=PARAMS,
    quant_layers=QUANT_LAYERS,
    apply=apply,
    loss_and_correct=vision_loss_and_correct(apply),
    input_spec={
        "train": {"x": ((128, 32, 32, 3), "f32"), "y": ((128,), "i32")},
        "eval": {"x": ((256, 32, 32, 3), "f32"), "y": ((256,), "i32")},
    },
    task="vision",
)
