"""Back-compat shim: the Layer-2 model zoo lives in ``compile.models``."""

from .models import REGISTRY, Model  # noqa: F401
